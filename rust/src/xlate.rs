//! The address-translation subsystem: per-SM TLB hierarchies and a
//! bounded page-table-walker model behind one seam the engine drives
//! per access.
//!
//! Two models live behind [`TranslationUnit`], selected purely by
//! configuration (`tlb_l1_entries` in [`SystemConfig`]):
//!
//! * [`TranslationUnit::Legacy`] (`tlb_l1_entries = 0`, the default) —
//!   the frozen model every golden number is locked against: one flat
//!   per-SM TLB of `tlb_entries` and a constant `tlb_miss_ns` walk
//!   cost. Its [`access`](TranslationUnit::access) replays the engine's
//!   historical miss sequence operation for operation (one f64 add on a
//!   miss, nothing on a hit), so existing reports stay bit-exact — the
//!   differential and golden suites enforce this.
//! * [`TranslationUnit::Hier`] — the NDPage-motivated hierarchy
//!   (arXiv 2502.14220): split per-SM L1 TLBs (one per page size, so a
//!   2 MB entry covers a whole promoted frame), a unified per-SM L2
//!   probing both page sizes, and a *global* pool of `ptw_slots`
//!   page-table walkers. A walk occupies a slot for
//!   `levels x ptw_level_ns` — [`WALK_LEVELS_BASE`] levels for base
//!   pages, [`WALK_LEVELS_HUGE`] for huge pages (the huge walk
//!   terminates at the directory level) — and when every slot is busy
//!   the access queues behind the earliest-free one. Queue cycles are
//!   accounted separately from walk service cycles, which is exactly
//!   the signal that distinguishes translation *pressure* (not enough
//!   walkers) from translation *cost* (walks themselves).
//!
//! Timing contract: [`TranslationUnit::access`] returns the instant the
//! translation is available plus the PTE; the engine layers everything
//! downstream on top (migration, interconnect hops, DRAM dispatch).
//! Translation prices the lookup but never decides *where* data lives,
//! so local/remote access counts stay model-independent — the same
//! invariant the DRAM backends honor.

use crate::addr::VirtualAddress;
use crate::config::SystemConfig;
use crate::stats::XlateStats;
use crate::vm::{Pte, Tlb, VirtualMemory, HUGE_PAGE_BYTES};

/// Page-table levels referenced by a base-page walk (x86-style 4-level).
pub const WALK_LEVELS_BASE: f64 = 4.0;
/// Levels referenced by a huge-page walk: the 2 MB mapping lives one
/// level up, so the walk terminates early.
pub const WALK_LEVELS_HUGE: f64 = 3.0;

/// The per-SM flat TLBs plus the frozen constant-cost walk.
pub struct Legacy {
    tlbs: Vec<Tlb>,
    /// `tlb_miss_ns` converted to SM cycles (hoisted once, exactly as
    /// the engine's historical loop did).
    miss_cycles: f64,
    page_shift: u32,
}

/// The hierarchical L1/L2/PTW pipeline.
pub struct Hier {
    /// Per-SM split L1 for base pages (tagged by base-page VPN).
    l1_base: Vec<Tlb>,
    /// Per-SM split L1 for 2 MB pages (tagged by huge-frame number; one
    /// entry covers a whole promoted frame).
    l1_huge: Vec<Tlb>,
    /// Per-SM unified L2, probed under both page sizes. Tags disambiguate
    /// the size in the low bit: `vpn << 1` for base, `(frame << 1) | 1`
    /// for huge.
    l2: Vec<Tlb>,
    /// Free-at times of the global walker pool (`ptw_slots` long).
    walkers: Vec<f64>,
    l2_hit_cycles: f64,
    /// One page-table level reference in SM cycles.
    level_cycles: f64,
    page_shift: u32,
    /// `log2(base pages per 2 MB frame)`; 0 when the page size cannot
    /// tile a huge frame (then nothing is ever tagged huge).
    huge_shift: u32,
    /// `pages per frame - 1`, the in-frame page index mask.
    span_mask: u64,
    // Own counters (the embedded `Tlb` hit/miss counters are ignored:
    // the unified L2 is probed under up to two tags per access, which
    // would double-count misses).
    l1_hits: u64,
    l1_misses: u64,
    l2_hits: u64,
    l2_misses: u64,
    walks: u64,
    walk_cycles: f64,
    walk_queue_cycles: f64,
}

/// The seam the engine drives: either the frozen legacy model or the
/// hierarchical pipeline, selected once from configuration.
pub enum TranslationUnit {
    /// Flat per-SM TLB + constant walk cost (the frozen default).
    Legacy(Legacy),
    /// Split L1s + unified L2 + bounded walker pool.
    Hier(Hier),
}

impl TranslationUnit {
    /// Build the unit for `n_sms` SMs. `cyc` is the engine's
    /// cycles-per-ns factor — passed in (not recomputed) so the legacy
    /// path's `tlb_miss_ns * cyc` is the engine's historical expression
    /// bit for bit.
    pub fn new(cfg: &SystemConfig, n_sms: usize, cyc: f64) -> Self {
        let page_shift = cfg.page_size.trailing_zeros();
        if cfg.tlb_l1_entries == 0 {
            return TranslationUnit::Legacy(Legacy {
                tlbs: (0..n_sms).map(|_| Tlb::new(cfg.tlb_entries)).collect(),
                miss_cycles: cfg.tlb_miss_ns * cyc,
                page_shift,
            });
        }
        let span = if cfg.page_size <= HUGE_PAGE_BYTES && HUGE_PAGE_BYTES % cfg.page_size == 0 {
            HUGE_PAGE_BYTES / cfg.page_size
        } else {
            1
        };
        TranslationUnit::Hier(Hier {
            l1_base: (0..n_sms)
                .map(|_| Tlb::with_ways(cfg.tlb_l1_entries, cfg.tlb_l1_ways))
                .collect(),
            l1_huge: (0..n_sms)
                .map(|_| Tlb::with_ways(cfg.tlb_l1_entries, cfg.tlb_l1_ways))
                .collect(),
            l2: (0..n_sms)
                .map(|_| Tlb::with_ways(cfg.tlb_l2_entries, cfg.tlb_l2_ways))
                .collect(),
            walkers: vec![0.0; cfg.ptw_slots],
            l2_hit_cycles: cfg.tlb_l2_hit_ns * cyc,
            level_cycles: cfg.ptw_level_ns * cyc,
            page_shift,
            huge_shift: span.trailing_zeros(),
            span_mask: span - 1,
            l1_hits: 0,
            l1_misses: 0,
            l2_hits: 0,
            l2_misses: 0,
            walks: 0,
            walk_cycles: 0.0,
            walk_queue_cycles: 0.0,
        })
    }

    /// Translate one access issued at `now` on SM `sm`: returns the time
    /// the translation is ready and the page's PTE. Panics (like the
    /// engine always has) if `va` lies beyond every mapped object.
    pub fn access(
        &mut self,
        sm: usize,
        now: f64,
        va: VirtualAddress,
        vm: &VirtualMemory,
    ) -> (f64, Pte) {
        match self {
            TranslationUnit::Legacy(u) => {
                let vpn = va.0 >> u.page_shift;
                match u.tlbs[sm].lookup(vpn) {
                    Some(pte) => (now, pte),
                    None => {
                        // The engine's historical miss sequence, verbatim:
                        // one constant-cost walk, then fill.
                        let t = now + u.miss_cycles;
                        let pte = vm
                            .pte_of(va)
                            .expect("workload access beyond mapped object");
                        u.tlbs[sm].fill(vpn, pte);
                        (t, pte)
                    }
                }
            }
            TranslationUnit::Hier(u) => u.access(sm, now, va, vm),
        }
    }

    /// Re-install a translation the engine just changed under the TLBs
    /// (page migration rewrites the PTE in place). Mirrors the frozen
    /// `tlb.fill` the legacy loop performed after a migration; migrated
    /// pages are always base pages, so the hierarchy fills its base L1
    /// and the unified L2.
    pub fn install(&mut self, sm: usize, va: VirtualAddress, pte: Pte) {
        match self {
            TranslationUnit::Legacy(u) => {
                u.tlbs[sm].fill(va.0 >> u.page_shift, pte);
            }
            TranslationUnit::Hier(u) => {
                let vpn = va.0 >> u.page_shift;
                u.l1_base[sm].fill(vpn, pte);
                u.l2[sm].fill(vpn << 1, pte);
            }
        }
    }

    /// Drop every translation SM `sm` holds (an address-space switch on
    /// a time-shared SM). Hit/miss counters survive.
    pub fn flush(&mut self, sm: usize) {
        match self {
            TranslationUnit::Legacy(u) => u.tlbs[sm].flush(),
            TranslationUnit::Hier(u) => {
                u.l1_base[sm].flush();
                u.l1_huge[sm].flush();
                u.l2[sm].flush();
            }
        }
    }

    /// First-level hit accounting as `(hits, lookups)` — the numbers the
    /// report's `tlb_hit_rate` has always been computed from. Legacy
    /// sums the per-SM TLB counters exactly as the engine's historical
    /// epilogue did; the hierarchy reports its L1 aggregate.
    pub fn hit_totals(&self) -> (u64, u64) {
        match self {
            TranslationUnit::Legacy(u) => {
                let hits: u64 = u.tlbs.iter().map(|t| t.hits).sum();
                let total: u64 = u.tlbs.iter().map(|t| t.hits + t.misses).sum();
                (hits, total)
            }
            TranslationUnit::Hier(u) => (u.l1_hits, u.l1_hits + u.l1_misses),
        }
    }

    /// Shape the run's translation results. `None` under the legacy
    /// model — its reports are frozen, and conditional emission is what
    /// keeps them byte-identical. `span_cycles` is the run makespan and
    /// `n_sms` the SM count; together they form the total-execution
    /// denominator of the walk-stall share.
    pub fn stats(&self, vm: &VirtualMemory, span_cycles: f64, n_sms: usize) -> Option<XlateStats> {
        let u = match self {
            TranslationUnit::Legacy(_) => return None,
            TranslationUnit::Hier(u) => u,
        };
        let rate = |num: u64, den: u64| if den == 0 { 0.0 } else { num as f64 / den as f64 };
        let total_cycles = span_cycles * n_sms as f64;
        Some(XlateStats {
            l1_hits: u.l1_hits,
            l1_misses: u.l1_misses,
            l2_hits: u.l2_hits,
            l2_misses: u.l2_misses,
            walks: u.walks,
            l1_hit_rate: rate(u.l1_hits, u.l1_hits + u.l1_misses),
            l2_hit_rate: rate(u.l2_hits, u.l2_hits + u.l2_misses),
            walk_cycles: u.walk_cycles,
            walk_queue_cycles: u.walk_queue_cycles,
            walk_stall_share: if total_cycles > 0.0 {
                (u.walk_cycles + u.walk_queue_cycles) / total_cycles
            } else {
                0.0
            },
            huge_pages: vm.huge_frames(),
            huge_coverage: vm.huge_coverage(),
        })
    }
}

impl Hier {
    /// A huge L1/L2 entry stores the *frame-base* PTE; reconstruct the
    /// per-page PTE for `vpn` from it. Promotion maps frames 2 MB-aligned
    /// in both spaces, so base ppn + in-frame index is exact.
    #[inline]
    fn expand(base: Pte, vpn: u64, span_mask: u64) -> Pte {
        Pte {
            ppn: base.ppn + (vpn & span_mask),
            ..base
        }
    }

    fn access(&mut self, sm: usize, now: f64, va: VirtualAddress, vm: &VirtualMemory) -> (f64, Pte) {
        let vpn = va.0 >> self.page_shift;
        let frame = vpn >> self.huge_shift;
        // L1 probes overlap the access pipeline: hits cost nothing, like
        // the legacy TLB hit.
        if let Some(base) = self.l1_huge[sm].lookup(frame) {
            self.l1_hits += 1;
            return (now, Self::expand(base, vpn, self.span_mask));
        }
        if let Some(pte) = self.l1_base[sm].lookup(vpn) {
            self.l1_hits += 1;
            return (now, pte);
        }
        self.l1_misses += 1;
        let t = now + self.l2_hit_cycles;
        if let Some(base) = self.l2[sm].lookup((frame << 1) | 1) {
            self.l2_hits += 1;
            self.l1_huge[sm].fill(frame, base);
            return (t, Self::expand(base, vpn, self.span_mask));
        }
        if let Some(pte) = self.l2[sm].lookup(vpn << 1) {
            self.l2_hits += 1;
            self.l1_base[sm].fill(vpn, pte);
            return (t, pte);
        }
        self.l2_misses += 1;
        // Both levels missed: take a page walk on the first free slot of
        // the global pool. A fully-busy pool queues the access — that
        // wait is translation *pressure*, kept separate from the walk
        // service time.
        let pte = vm
            .pte_of(va)
            .expect("workload access beyond mapped object");
        let levels = if pte.huge {
            WALK_LEVELS_HUGE
        } else {
            WALK_LEVELS_BASE
        };
        let latency = levels * self.level_cycles;
        let mut slot = 0;
        for (i, &free) in self.walkers.iter().enumerate() {
            if free < self.walkers[slot] {
                slot = i;
            }
        }
        let start = if self.walkers[slot] > t {
            self.walkers[slot]
        } else {
            t
        };
        self.walk_queue_cycles += start - t;
        let done = start + latency;
        self.walkers[slot] = done;
        self.walks += 1;
        self.walk_cycles += latency;
        if pte.huge {
            let base = Pte {
                ppn: pte.ppn - (vpn & self.span_mask),
                ..pte
            };
            self.l1_huge[sm].fill(frame, base);
            self.l2[sm].fill((frame << 1) | 1, base);
        } else {
            self.l1_base[sm].fill(vpn, pte);
            self.l2[sm].fill(vpn << 1, pte);
        }
        (done, pte)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::addr::Granularity;

    fn small_cfg() -> SystemConfig {
        SystemConfig::test_small()
    }

    fn vm_with_pages(cfg: &SystemConfig, pages: u64) -> (VirtualMemory, VirtualAddress) {
        let mut vm = VirtualMemory::new(cfg);
        let base = vm.map_fgp(pages).unwrap();
        (vm, base)
    }

    #[test]
    fn legacy_replays_the_flat_miss_cost() {
        let cfg = small_cfg();
        assert_eq!(cfg.tlb_l1_entries, 0);
        let cyc = cfg.cycles_per_ns();
        let (vm, base) = vm_with_pages(&cfg, 4);
        let mut xl = TranslationUnit::new(&cfg, 2, cyc);
        let (t, pte) = xl.access(0, 100.0, base, &vm);
        assert_eq!(t, 100.0 + cfg.tlb_miss_ns * cyc);
        assert_eq!(pte.granularity, Granularity::Fgp);
        // Second access to the same page: a free hit.
        let (t2, _) = xl.access(0, 200.0, base + 8, &vm);
        assert_eq!(t2, 200.0);
        // Another SM has its own TLB and misses independently.
        let (t3, _) = xl.access(1, 200.0, base, &vm);
        assert_eq!(t3, 200.0 + cfg.tlb_miss_ns * cyc);
        assert_eq!(xl.hit_totals(), (1, 3));
        assert!(xl.stats(&vm, 1000.0, 2).is_none());
    }

    #[test]
    fn hier_walks_then_hits_the_levels_in_order() {
        let mut cfg = small_cfg();
        cfg.tlb_l1_entries = 1; // one-entry L1: easy to evict
        cfg.tlb_l1_ways = 1;
        cfg.tlb_l2_entries = 64;
        cfg.ptw_slots = 4;
        cfg.validate().unwrap();
        let cyc = cfg.cycles_per_ns();
        let (vm, base) = vm_with_pages(&cfg, 4);
        let mut xl = TranslationUnit::new(&cfg, 1, cyc);
        let l2_hit = cfg.tlb_l2_hit_ns * cyc;
        let walk = WALK_LEVELS_BASE * cfg.ptw_level_ns * cyc;
        // Cold: miss L1+L2, walk 4 levels after the L2 probe.
        let (t, _) = xl.access(0, 0.0, base, &vm);
        assert_eq!(t, l2_hit + walk);
        // Same page again: L1 hit, free.
        let (t, _) = xl.access(0, 1000.0, base, &vm);
        assert_eq!(t, 1000.0);
        // Touch a second page (evicts page 0 from the 1-entry L1)...
        let _ = xl.access(0, 2000.0, base + cfg.page_size, &vm);
        // ...so page 0 now hits in the unified L2, not L1.
        let (t, _) = xl.access(0, 3000.0, base, &vm);
        assert_eq!(t, 3000.0 + l2_hit);
        let s = xl.stats(&vm, 10_000.0, 1).unwrap();
        assert_eq!(s.l1_hits, 1);
        assert_eq!(s.l1_misses, 3);
        assert_eq!(s.l2_hits, 1);
        assert_eq!(s.walks, 2);
        assert_eq!(s.walk_cycles, 2.0 * walk);
        assert_eq!(s.walk_queue_cycles, 0.0);
        assert!(s.walk_stall_share > 0.0);
    }

    #[test]
    fn busy_walkers_queue_and_account_the_wait() {
        let mut cfg = small_cfg();
        cfg.tlb_l1_entries = 8;
        cfg.ptw_slots = 1; // a single walker: concurrent walks serialize
        cfg.validate().unwrap();
        let cyc = cfg.cycles_per_ns();
        let (vm, base) = vm_with_pages(&cfg, 4);
        let mut xl = TranslationUnit::new(&cfg, 2, cyc);
        let l2_hit = cfg.tlb_l2_hit_ns * cyc;
        let walk = WALK_LEVELS_BASE * cfg.ptw_level_ns * cyc;
        let (t1, _) = xl.access(0, 0.0, base, &vm);
        assert_eq!(t1, l2_hit + walk);
        // A different SM walks a different page at the same instant: it
        // queues behind the busy walker instead of walking in parallel.
        let (t2, _) = xl.access(1, 0.0, base + cfg.page_size, &vm);
        assert_eq!(t2, l2_hit + 2.0 * walk);
        let s = xl.stats(&vm, 10_000.0, 2).unwrap();
        assert_eq!(s.walks, 2);
        // The second walk waited out the first's full service time.
        assert!((s.walk_queue_cycles - walk).abs() < 1e-9);
    }

    #[test]
    fn one_huge_entry_covers_the_whole_frame() {
        let mut cfg = small_cfg();
        cfg.tlb_l1_entries = 8;
        cfg.huge_pages = true;
        cfg.validate().unwrap();
        let cyc = cfg.cycles_per_ns();
        let span = HUGE_PAGE_BYTES / cfg.page_size;
        let mut vm = VirtualMemory::new(&cfg);
        let base = vm.map_cgp(span, |_| 1).unwrap();
        assert_eq!(vm.huge_frames(), 1);
        let mut xl = TranslationUnit::new(&cfg, 1, cyc);
        let l2_hit = cfg.tlb_l2_hit_ns * cyc;
        let walk = WALK_LEVELS_HUGE * cfg.ptw_level_ns * cyc;
        // Cold walk: 3 levels, not 4 (the huge mapping sits a level up).
        let (t, pte) = xl.access(0, 0.0, base, &vm);
        assert!(pte.huge);
        assert_eq!(t, l2_hit + walk);
        // Every other base page of the frame hits the huge L1 entry.
        for k in 1..span {
            let (t, pte) = xl.access(0, 5000.0, base + k * cfg.page_size, &vm);
            assert_eq!(t, 5000.0, "page {k} missed the huge entry");
            assert!(pte.huge);
            // The reconstructed PTE walks the frame contiguously.
            assert_eq!(pte.ppn, xl_access_ppn_base(&vm, base) + k);
        }
        let s = xl.stats(&vm, 10_000.0, 1).unwrap();
        assert_eq!(s.walks, 1);
        assert_eq!(s.huge_pages, 1);
        assert!(s.huge_coverage > 0.99);
    }

    /// Frame-base ppn of the page at `va` (test helper).
    fn xl_access_ppn_base(vm: &VirtualMemory, va: VirtualAddress) -> u64 {
        vm.pte_of(va).unwrap().ppn
    }

    #[test]
    fn flush_drops_translations_but_not_counters() {
        let cfg = small_cfg();
        let cyc = cfg.cycles_per_ns();
        let (vm, base) = vm_with_pages(&cfg, 2);
        let mut xl = TranslationUnit::new(&cfg, 1, cyc);
        let _ = xl.access(0, 0.0, base, &vm);
        let (t, _) = xl.access(0, 10.0, base, &vm);
        assert_eq!(t, 10.0); // hit
        xl.flush(0);
        let (t, _) = xl.access(0, 20.0, base, &vm);
        assert_eq!(t, 20.0 + cfg.tlb_miss_ns * cyc); // cold again
        assert_eq!(xl.hit_totals(), (1, 3));
    }
}
