//! Property tests (via `coda::proptest_lite`) for the dual-mode address
//! mapping and the PTE path:
//!
//! * FGP and CGP address -> (stack, stack-local offset) decode is a
//!   bijection over random pages: `compose . decompose = id` and
//!   `decompose . compose = id`, under plain and XOR-folded mappings, for
//!   4 KB and 2 MB pages, across stack counts.
//! * The granularity bit round-trips through the PTE path in `vm.rs`: a
//!   page mapped FGP/CGP reads back with the same bit from `pte_of` and
//!   `translate`, and CGP pages resolve to their requested stack.
//! * The `VirtualAddress` / `PhysicalAddress` newtypes are transparent:
//!   `From`/`Into`/`Add` preserve the underlying bits exactly, and the
//!   typed translate path equals the raw PPN/offset arithmetic it wraps.

// Case generators mutate a default config; the lint's suggested struct
// literal obscures which knobs each property varies.
#![allow(clippy::field_reassign_with_default)]

use coda::addr::{
    large_page_mapper, AddressMapper, Granularity, PhysicalAddress, VirtualAddress,
};
use coda::config::SystemConfig;
use coda::proptest_lite::{run_prop, PropConfig};
use coda::rng::Rng;
use coda::vm::VirtualMemory;

/// Random (config, mapper-variant, address) cases for the bijection.
#[test]
fn prop_dual_mode_decode_is_a_bijection() {
    run_prop(
        PropConfig {
            cases: 128,
            seed: 0xADD2,
        },
        |rng: &mut Rng| {
            let mut cfg = SystemConfig::default();
            cfg.num_stacks = 1 << rng.range(0, 4); // 1..8
            cfg.fgp_interleave = 128 << rng.range(0, 2); // 128 or 256
            let fold = rng.chance(0.5);
            let large = rng.chance(0.25);
            // 48-bit physical addresses, page-aligned plus a random offset.
            let addrs: Vec<u64> = (0..64)
                .map(|_| rng.below(1u64 << 48))
                .collect();
            (cfg, fold, large, addrs)
        },
        |(cfg, fold, large, addrs)| {
            cfg.validate().map_err(|e| e.to_string())?;
            let mapper = if *large {
                large_page_mapper(cfg)
            } else {
                AddressMapper::new(cfg)
            }
            .with_xor_fold(*fold);
            for &addr in addrs {
                for g in [Granularity::Fgp, Granularity::Cgp] {
                    let (stack, local) = mapper.decompose(addr, g);
                    if stack != mapper.stack_of(addr, g) {
                        return Err(format!("decompose stack mismatch at {addr:#x}"));
                    }
                    if stack >= cfg.num_stacks {
                        return Err(format!("stack {stack} out of range at {addr:#x}"));
                    }
                    let back = mapper.compose(stack, local, g);
                    if back != addr {
                        return Err(format!(
                            "compose(decompose({addr:#x})) = {back:#x} ({g:?})"
                        ));
                    }
                    // Inverse direction: a synthetic (stack, local) pair
                    // round-trips too, so decode is onto as well as 1-1.
                    let synth_stack = (stack + 1) % cfg.num_stacks;
                    let synth = mapper.compose(synth_stack, local, g);
                    if mapper.decompose(synth, g) != (synth_stack, local) {
                        return Err(format!(
                            "decompose(compose({synth_stack}, {local:#x})) diverged ({g:?})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Distinct addresses never alias one (stack, local) pair — checked
/// directly over a dense window so off-by-one bit errors can't hide.
#[test]
fn prop_decode_has_no_collisions_in_a_window() {
    run_prop(
        PropConfig {
            cases: 32,
            seed: 0xADD3,
        },
        |rng: &mut Rng| {
            let base = rng.below(1u64 << 40) & !0xFFF;
            let fold = rng.chance(0.5);
            (base, fold)
        },
        |(base, fold)| {
            let cfg = SystemConfig::default();
            let mapper = AddressMapper::new(&cfg).with_xor_fold(*fold);
            for g in [Granularity::Fgp, Granularity::Cgp] {
                let mut seen = std::collections::HashSet::new();
                for line in 0..256u64 {
                    let addr = base + line * cfg.line_size;
                    if !seen.insert(mapper.decompose(addr, g)) {
                        return Err(format!("collision at {addr:#x} ({g:?})"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Granularity-bit round-trip through the PTE path: map a random mix of
/// FGP/CGP segments and check every page reads back with the bit it was
/// mapped with, through both `pte_of` and `translate`, and that CGP pages
/// land whole on the requested stack.
#[test]
fn prop_granularity_bit_roundtrips_through_pte() {
    run_prop(
        PropConfig {
            cases: 48,
            seed: 0x97E0,
        },
        |rng: &mut Rng| {
            let segs: Vec<(bool, u64, usize)> = (0..10)
                .map(|_| {
                    (
                        rng.chance(0.5),
                        rng.range(1, 8),
                        rng.below(4) as usize,
                    )
                })
                .collect();
            segs
        },
        |segs| {
            let cfg = SystemConfig::test_small();
            let mapper = AddressMapper::new(&cfg);
            let mut vm = VirtualMemory::new(&cfg);
            for (is_cgp, pages, stack) in segs {
                let want = if *is_cgp {
                    Granularity::Cgp
                } else {
                    Granularity::Fgp
                };
                let base = if *is_cgp {
                    vm.map_cgp(*pages, |_| *stack)
                } else {
                    vm.map_fgp(*pages)
                }
                .map_err(|e| e.to_string())?;
                for pg in 0..*pages {
                    let vaddr = base + pg * cfg.page_size;
                    let pte = vm.pte_of(vaddr).ok_or("missing PTE")?;
                    if pte.granularity != want {
                        return Err(format!("PTE bit lost at vaddr {:#x}", vaddr.0));
                    }
                    let (paddr, g) = vm.translate(vaddr + 123).ok_or("unmapped")?;
                    if g != want {
                        return Err(format!("translate bit lost at vaddr {:#x}", vaddr.0));
                    }
                    if *is_cgp {
                        for off in [0u64, cfg.page_size / 2, cfg.page_size - 1] {
                            let (p, g) = vm.translate(vaddr + off).ok_or("unmapped")?;
                            if mapper.stack_of(p, g) != *stack {
                                return Err(format!(
                                    "CGP page at {:#x} strayed off stack {stack}",
                                    vaddr.0
                                ));
                            }
                        }
                    } else {
                        // An FGP page's stripes must cover every stack.
                        let mut hit = vec![false; cfg.num_stacks];
                        for off in (0..cfg.page_size).step_by(cfg.fgp_interleave as usize) {
                            let (p, g) = vm.translate(vaddr + off).ok_or("unmapped")?;
                            hit[mapper.stack_of(p, g)] = true;
                        }
                        if hit.iter().any(|h| !h) {
                            return Err(format!("FGP page at {:#x} skips a stack", vaddr.0));
                        }
                    }
                    let _ = paddr;
                }
            }
            Ok(())
        },
    );
}

/// The VA/PA newtypes must be pure relabelings of `u64`: conversions and
/// offset arithmetic never perturb bits, and a typed `translate` result
/// decomposes back into exactly the PPN and page offset of the raw math.
#[test]
fn prop_va_pa_newtypes_roundtrip() {
    run_prop(
        PropConfig {
            cases: 96,
            seed: 0x7A9A,
        },
        |rng: &mut Rng| {
            let raw = rng.below(1u64 << 48);
            let off = rng.below(1u64 << 20);
            (raw, off)
        },
        |(raw, off)| {
            // From / Into round-trips are the identity on both newtypes.
            let va = VirtualAddress::from(*raw);
            if va.0 != *raw || u64::from(va) != *raw {
                return Err(format!("VirtualAddress round-trip lost {raw:#x}"));
            }
            let pa = PhysicalAddress::from(*raw);
            if pa.0 != *raw || u64::from(pa) != *raw {
                return Err(format!("PhysicalAddress round-trip lost {raw:#x}"));
            }
            // Offsetting commutes with the wrap: wrap-then-add == add-then-wrap.
            // (raw < 2^48 and off < 2^20, so the sum cannot overflow.)
            if (va + *off).0 != *raw + *off {
                return Err(format!("VA + {off:#x} diverged from raw add"));
            }
            if (pa + *off).0 != *raw + *off {
                return Err(format!("PA + {off:#x} diverged from raw add"));
            }
            // The typed translate path is the raw PPN/offset compose: a
            // mapped page's physical address splits back into the PTE's PPN
            // and the VA's in-page offset.
            let cfg = SystemConfig::test_small();
            let mut vm = VirtualMemory::new(&cfg);
            let base = vm.map_fgp(4).map_err(|e| e.to_string())?;
            let vaddr = base + (off % (4 * cfg.page_size));
            let pte = vm.pte_of(vaddr).ok_or("missing PTE")?;
            let (paddr, _) = vm.translate(vaddr).ok_or("unmapped")?;
            let page_shift = cfg.page_size.trailing_zeros();
            if paddr.0 >> page_shift != pte.ppn {
                return Err(format!(
                    "translate PPN {:#x} != PTE PPN {:#x}",
                    paddr.0 >> page_shift,
                    pte.ppn
                ));
            }
            if paddr.0 & (cfg.page_size - 1) != vaddr.0 & (cfg.page_size - 1) {
                return Err("translate changed the in-page offset".into());
            }
            Ok(())
        },
    );
}
