//! Property tests (via `coda::proptest_lite`) for the dual-mode address
//! mapping and the PTE path:
//!
//! * FGP and CGP address -> (stack, stack-local offset) decode is a
//!   bijection over random pages: `compose . decompose = id` and
//!   `decompose . compose = id`, under plain and XOR-folded mappings, for
//!   4 KB and 2 MB pages, across stack counts.
//! * The granularity bit round-trips through the PTE path in `vm.rs`: a
//!   page mapped FGP/CGP reads back with the same bit from `pte_of` and
//!   `translate`, and CGP pages resolve to their requested stack.

// Case generators mutate a default config; the lint's suggested struct
// literal obscures which knobs each property varies.
#![allow(clippy::field_reassign_with_default)]

use coda::addr::{large_page_mapper, AddressMapper, Granularity};
use coda::config::SystemConfig;
use coda::proptest_lite::{run_prop, PropConfig};
use coda::rng::Rng;
use coda::vm::VirtualMemory;

/// Random (config, mapper-variant, address) cases for the bijection.
#[test]
fn prop_dual_mode_decode_is_a_bijection() {
    run_prop(
        PropConfig {
            cases: 128,
            seed: 0xADD2,
        },
        |rng: &mut Rng| {
            let mut cfg = SystemConfig::default();
            cfg.num_stacks = 1 << rng.range(0, 4); // 1..8
            cfg.fgp_interleave = 128 << rng.range(0, 2); // 128 or 256
            let fold = rng.chance(0.5);
            let large = rng.chance(0.25);
            // 48-bit physical addresses, page-aligned plus a random offset.
            let addrs: Vec<u64> = (0..64)
                .map(|_| rng.below(1u64 << 48))
                .collect();
            (cfg, fold, large, addrs)
        },
        |(cfg, fold, large, addrs)| {
            cfg.validate().map_err(|e| e.to_string())?;
            let mapper = if *large {
                large_page_mapper(cfg)
            } else {
                AddressMapper::new(cfg)
            }
            .with_xor_fold(*fold);
            for &addr in addrs {
                for g in [Granularity::Fgp, Granularity::Cgp] {
                    let (stack, local) = mapper.decompose(addr, g);
                    if stack != mapper.stack_of(addr, g) {
                        return Err(format!("decompose stack mismatch at {addr:#x}"));
                    }
                    if stack >= cfg.num_stacks {
                        return Err(format!("stack {stack} out of range at {addr:#x}"));
                    }
                    let back = mapper.compose(stack, local, g);
                    if back != addr {
                        return Err(format!(
                            "compose(decompose({addr:#x})) = {back:#x} ({g:?})"
                        ));
                    }
                    // Inverse direction: a synthetic (stack, local) pair
                    // round-trips too, so decode is onto as well as 1-1.
                    let synth_stack = (stack + 1) % cfg.num_stacks;
                    let synth = mapper.compose(synth_stack, local, g);
                    if mapper.decompose(synth, g) != (synth_stack, local) {
                        return Err(format!(
                            "decompose(compose({synth_stack}, {local:#x})) diverged ({g:?})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Distinct addresses never alias one (stack, local) pair — checked
/// directly over a dense window so off-by-one bit errors can't hide.
#[test]
fn prop_decode_has_no_collisions_in_a_window() {
    run_prop(
        PropConfig {
            cases: 32,
            seed: 0xADD3,
        },
        |rng: &mut Rng| {
            let base = rng.below(1u64 << 40) & !0xFFF;
            let fold = rng.chance(0.5);
            (base, fold)
        },
        |(base, fold)| {
            let cfg = SystemConfig::default();
            let mapper = AddressMapper::new(&cfg).with_xor_fold(*fold);
            for g in [Granularity::Fgp, Granularity::Cgp] {
                let mut seen = std::collections::HashSet::new();
                for line in 0..256u64 {
                    let addr = base + line * cfg.line_size;
                    if !seen.insert(mapper.decompose(addr, g)) {
                        return Err(format!("collision at {addr:#x} ({g:?})"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Granularity-bit round-trip through the PTE path: map a random mix of
/// FGP/CGP segments and check every page reads back with the bit it was
/// mapped with, through both `pte_of` and `translate`, and that CGP pages
/// land whole on the requested stack.
#[test]
fn prop_granularity_bit_roundtrips_through_pte() {
    run_prop(
        PropConfig {
            cases: 48,
            seed: 0x97E0,
        },
        |rng: &mut Rng| {
            let segs: Vec<(bool, u64, usize)> = (0..10)
                .map(|_| {
                    (
                        rng.chance(0.5),
                        rng.range(1, 8),
                        rng.below(4) as usize,
                    )
                })
                .collect();
            segs
        },
        |segs| {
            let cfg = SystemConfig::test_small();
            let mapper = AddressMapper::new(&cfg);
            let mut vm = VirtualMemory::new(&cfg);
            for (is_cgp, pages, stack) in segs {
                let want = if *is_cgp {
                    Granularity::Cgp
                } else {
                    Granularity::Fgp
                };
                let base = if *is_cgp {
                    vm.map_cgp(*pages, |_| *stack)
                } else {
                    vm.map_fgp(*pages)
                }
                .map_err(|e| e.to_string())?;
                for pg in 0..*pages {
                    let vaddr = base + pg * cfg.page_size;
                    let pte = vm.pte_of(vaddr).ok_or("missing PTE")?;
                    if pte.granularity != want {
                        return Err(format!("PTE bit lost at vaddr {vaddr:#x}"));
                    }
                    let (paddr, g) = vm.translate(vaddr + 123).ok_or("unmapped")?;
                    if g != want {
                        return Err(format!("translate bit lost at vaddr {vaddr:#x}"));
                    }
                    if *is_cgp {
                        for off in [0u64, cfg.page_size / 2, cfg.page_size - 1] {
                            let (p, g) = vm.translate(vaddr + off).ok_or("unmapped")?;
                            if mapper.stack_of(p, g) != *stack {
                                return Err(format!(
                                    "CGP page at {vaddr:#x} strayed off stack {stack}"
                                ));
                            }
                        }
                    } else {
                        // An FGP page's stripes must cover every stack.
                        let mut hit = vec![false; cfg.num_stacks];
                        for off in (0..cfg.page_size).step_by(cfg.fgp_interleave as usize) {
                            let (p, g) = vm.translate(vaddr + off).ok_or("unmapped")?;
                            hit[mapper.stack_of(p, g)] = true;
                        }
                        if hit.iter().any(|h| !h) {
                            return Err(format!("FGP page at {vaddr:#x} skips a stack"));
                        }
                    }
                    let _ = paddr;
                }
            }
            Ok(())
        },
    );
}
