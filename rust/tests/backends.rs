//! Backend-equivalence tests: the DRAM timing backend may shape *when*
//! things happen, never *what* happens. Placement, translation and
//! scheduling must not observe the backend; if they ever do, the
//! local/remote access splits below stop being byte-identical and this
//! file catches the leak.

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::{Coordinator, Mechanism};
use coda::workloads::suite;

fn fixed_cfg() -> SystemConfig {
    SystemConfig::test_small()
}

fn bank_cfg() -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = MemBackendKind::BankLevel;
    c
}

fn cycle_cfg() -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = MemBackendKind::CycleAccurate;
    c
}

/// FixedLatency vs BankLevel vs CycleAccurate on the small PR workload:
/// identical access counts (local/remote split, L2 hits, per-stack bytes)
/// under every non-migrating mechanism, while cycle counts are free to
/// differ. This is the tentpole's acceptance criterion: the backend may
/// shape *when*, never *what*.
#[test]
fn backends_agree_on_access_counts_for_pr() {
    let cf = fixed_cfg();
    let cb = bank_cfg();
    let cc = cycle_cfg();
    let wl_f = suite::build("PR", &cf).unwrap();
    let wl_b = suite::build("PR", &cb).unwrap();
    let wl_c = suite::build("PR", &cc).unwrap();
    let coord_f = Coordinator::new(cf.clone());
    let coord_b = Coordinator::new(cb.clone());
    let coord_c = Coordinator::new(cc.clone());
    for mech in [
        Mechanism::FgpOnly,
        Mechanism::CgpOnly,
        Mechanism::CgpFta,
        Mechanism::Coda,
        Mechanism::FgpAffinity,
    ] {
        let rf = coord_f.run(&wl_f, mech).unwrap();
        let rb = coord_b.run(&wl_b, mech).unwrap();
        let rc = coord_c.run(&wl_c, mech).unwrap();
        for (r, name) in [(&rb, "bank"), (&rc, "cycle")] {
            assert_eq!(
                rf.accesses,
                r.accesses,
                "{} vs {name}: access counts must not depend on the DRAM backend",
                mech.name()
            );
            assert_eq!(rf.stack_bytes, r.stack_bytes, "{} vs {name}", mech.name());
            assert_eq!(rf.remote_bytes, r.remote_bytes, "{} vs {name}", mech.name());
            assert_eq!(rf.cgp_pages, r.cgp_pages, "{} vs {name}", mech.name());
            // Timing is allowed — and expected — to differ: if it doesn't,
            // the backend selection never reached the simulator.
            assert!(
                (rf.cycles - r.cycles).abs() > 1e-9,
                "{}: identical cycles suggest the {name} backend was not dispatched",
                mech.name()
            );
        }
        assert_eq!(rf.mem_backend, "fixed");
        assert_eq!(rb.mem_backend, "bank");
        assert_eq!(rc.mem_backend, "cycle");
    }
}

/// The bank-level backend must surface its extra counters through the
/// report, and the fixed backend must leave them zero.
#[test]
fn bank_backend_reports_conflicts_and_refresh() {
    let cb = bank_cfg();
    let wl = suite::build("PR", &cb).unwrap();
    let rb = Coordinator::new(cb.clone())
        .run(&wl, Mechanism::FgpOnly)
        .unwrap();
    assert!(
        rb.bank_conflicts > 0,
        "an FGP PageRank run must produce some row-buffer conflicts"
    );
    assert!((0.0..=1.0).contains(&rb.row_hit_rate));

    let cf = fixed_cfg();
    let wl = suite::build("PR", &cf).unwrap();
    let rf = Coordinator::new(cf.clone())
        .run(&wl, Mechanism::FgpOnly)
        .unwrap();
    assert_eq!(rf.bank_conflicts, 0);
    assert_eq!(rf.refresh_stalls, 0);
}

/// The cycle backend surfaces its per-command counters through the
/// report; the coarser backends leave them zero.
#[test]
fn cycle_backend_reports_command_counters() {
    let cc = cycle_cfg();
    let wl = suite::build("PR", &cc).unwrap();
    let rc = Coordinator::new(cc.clone())
        .run(&wl, Mechanism::FgpOnly)
        .unwrap();
    assert!(rc.dram_acts > 0, "a PageRank run must activate rows");
    assert!(
        rc.dram_row_hits + rc.dram_row_misses + rc.bank_conflicts > 0,
        "every issued column command carries a row classification"
    );
    assert!((0.0..=1.0).contains(&rc.row_hit_rate));

    let rf = Coordinator::new(fixed_cfg())
        .run(&suite::build("PR", &fixed_cfg()).unwrap(), Mechanism::FgpOnly)
        .unwrap();
    assert_eq!(rf.dram_acts, 0);
    assert_eq!(rf.dram_precharges, 0);
    assert_eq!(rf.dram_wq_stalls, 0);
    assert_eq!(rf.dram_faw_stalls, 0);
}

/// All backends keep the paper's headline ordering: CODA beats FGP-Only
/// on a block-exclusive workload regardless of DRAM fidelity.
#[test]
fn coda_beats_fgp_under_both_backends() {
    for cfg in [fixed_cfg(), bank_cfg(), cycle_cfg()] {
        let wl = suite::build("DC", &cfg).unwrap();
        let coord = Coordinator::new(cfg.clone());
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        let s = coda.speedup_over(&fgp);
        // The fixed-backend bound (1.05) is locked in by the coordinator
        // unit tests; here the point is that higher DRAM fidelity cannot
        // flip the ordering, so a slightly looser bound avoids coupling
        // this test to exact bank-timing constants.
        assert!(
            s > 1.02,
            "backend {}: CODA speedup {s:.3} too small",
            cfg.mem_backend
        );
    }
}

/// Determinism holds under the bank-level and cycle backends too.
#[test]
fn bank_backend_is_deterministic_end_to_end() {
    for c in [bank_cfg(), cycle_cfg()] {
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("KM", &c).unwrap();
        let a = coord.run(&wl, Mechanism::Coda).unwrap();
        let b = coord.run(&wl, Mechanism::Coda).unwrap();
        assert_eq!(a.cycles, b.cycles, "{}", c.mem_backend);
        assert_eq!(a.accesses, b.accesses, "{}", c.mem_backend);
        assert_eq!(a.bank_conflicts, b.bank_conflicts, "{}", c.mem_backend);
        assert_eq!(a.refresh_stalls, b.refresh_stalls, "{}", c.mem_backend);
        assert_eq!(a.dram_acts, b.dram_acts, "{}", c.mem_backend);
    }
}

/// Degenerate-equivalence pin: with refresh pushed out of reach, an
/// all-read stream classifies identically under BankLevel and
/// CycleAccurate — row state is arrival-order + decode driven, and the
/// two models share both bit-for-bit. Where their semantics overlap, the
/// models must agree.
#[test]
fn degenerate_cycle_matches_bank_row_classification() {
    let mut cb = bank_cfg();
    cb.dram_trefi_ns = 1e12; // no refresh window inside the run
    let mut cc = cycle_cfg();
    cc.dram_trefi_ns = 1e12;
    let mut bank = coda::mem::make_backend(&cb);
    let mut cycle = coda::mem::make_backend(&cc);
    for i in 0..8192u64 {
        let addr = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFF_FFFF;
        let now = (i / 8) as f64;
        let rb = bank.access(now, addr, 128);
        let rc = cycle.access(now, addr, 128);
        assert_eq!(rb.row_hit, rc.row_hit, "access {i} at {addr:#x}");
    }
    let sb = bank.stats();
    let sc = cycle.stats();
    assert_eq!(sb.row_hits, sc.row_hits);
    assert_eq!(sb.row_misses, sc.row_misses);
    assert_eq!(sb.row_conflicts, sc.row_conflicts);
    assert_eq!(sb.bytes_served, sc.bytes_served);
    assert_eq!(sb.refresh_stalls, 0);
    assert_eq!(sc.refresh_stalls, 0);
}
