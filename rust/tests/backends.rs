//! Backend-equivalence tests: the DRAM timing backend may shape *when*
//! things happen, never *what* happens. Placement, translation and
//! scheduling must not observe the backend; if they ever do, the
//! local/remote access splits below stop being byte-identical and this
//! file catches the leak.

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::{Coordinator, Mechanism};
use coda::workloads::suite;

fn fixed_cfg() -> SystemConfig {
    SystemConfig::test_small()
}

fn bank_cfg() -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = MemBackendKind::BankLevel;
    c
}

/// FixedLatency vs BankLevel on the small PR workload: identical access
/// counts (local/remote split, L2 hits, per-stack bytes) under every
/// non-migrating mechanism, while cycle counts are free to differ.
#[test]
fn backends_agree_on_access_counts_for_pr() {
    let cf = fixed_cfg();
    let cb = bank_cfg();
    let wl_f = suite::build("PR", &cf).unwrap();
    let wl_b = suite::build("PR", &cb).unwrap();
    let coord_f = Coordinator::new(cf.clone());
    let coord_b = Coordinator::new(cb.clone());
    for mech in [
        Mechanism::FgpOnly,
        Mechanism::CgpOnly,
        Mechanism::CgpFta,
        Mechanism::Coda,
        Mechanism::FgpAffinity,
    ] {
        let rf = coord_f.run(&wl_f, mech).unwrap();
        let rb = coord_b.run(&wl_b, mech).unwrap();
        assert_eq!(
            rf.accesses,
            rb.accesses,
            "{}: access counts must not depend on the DRAM backend",
            mech.name()
        );
        assert_eq!(rf.stack_bytes, rb.stack_bytes, "{}", mech.name());
        assert_eq!(rf.remote_bytes, rb.remote_bytes, "{}", mech.name());
        assert_eq!(rf.cgp_pages, rb.cgp_pages, "{}", mech.name());
        assert_eq!(rf.mem_backend, "fixed");
        assert_eq!(rb.mem_backend, "bank");
        // Timing is allowed — and expected — to differ: if it doesn't, the
        // backend selection never reached the simulator.
        assert!(
            (rf.cycles - rb.cycles).abs() > 1e-9,
            "{}: identical cycles suggest the bank backend was not dispatched",
            mech.name()
        );
    }
}

/// The bank-level backend must surface its extra counters through the
/// report, and the fixed backend must leave them zero.
#[test]
fn bank_backend_reports_conflicts_and_refresh() {
    let cb = bank_cfg();
    let wl = suite::build("PR", &cb).unwrap();
    let rb = Coordinator::new(cb.clone())
        .run(&wl, Mechanism::FgpOnly)
        .unwrap();
    assert!(
        rb.bank_conflicts > 0,
        "an FGP PageRank run must produce some row-buffer conflicts"
    );
    assert!((0.0..=1.0).contains(&rb.row_hit_rate));

    let cf = fixed_cfg();
    let wl = suite::build("PR", &cf).unwrap();
    let rf = Coordinator::new(cf.clone())
        .run(&wl, Mechanism::FgpOnly)
        .unwrap();
    assert_eq!(rf.bank_conflicts, 0);
    assert_eq!(rf.refresh_stalls, 0);
}

/// Both backends keep the paper's headline ordering: CODA beats FGP-Only
/// on a block-exclusive workload regardless of DRAM fidelity.
#[test]
fn coda_beats_fgp_under_both_backends() {
    for cfg in [fixed_cfg(), bank_cfg()] {
        let wl = suite::build("DC", &cfg).unwrap();
        let coord = Coordinator::new(cfg.clone());
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        let s = coda.speedup_over(&fgp);
        // The fixed-backend bound (1.05) is locked in by the coordinator
        // unit tests; here the point is that higher DRAM fidelity cannot
        // flip the ordering, so a slightly looser bound avoids coupling
        // this test to exact bank-timing constants.
        assert!(
            s > 1.02,
            "backend {}: CODA speedup {s:.3} too small",
            cfg.mem_backend
        );
    }
}

/// Determinism holds under the bank-level backend too.
#[test]
fn bank_backend_is_deterministic_end_to_end() {
    let cb = bank_cfg();
    let coord = Coordinator::new(cb.clone());
    let wl = suite::build("KM", &cb).unwrap();
    let a = coord.run(&wl, Mechanism::Coda).unwrap();
    let b = coord.run(&wl, Mechanism::Coda).unwrap();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.accesses, b.accesses);
    assert_eq!(a.bank_conflicts, b.bank_conflicts);
    assert_eq!(a.refresh_stalls, b.refresh_stalls);
}
