//! Closes the bug class behind the historical `coda sweep --key/--values`
//! fix: an `--opt value` that `main.rs` consumes but `cli::VALUE_OPTS`
//! does not register silently parses as a *flag* followed by a stray
//! positional — the option's value is dropped without any error.
//!
//! These tests scan the binary's source (compiled in via `include_str!`)
//! for every `args.opt("...")` / `args.opt_parse("...")` /
//! `args.has_flag("...")` call site and cross-check the literals against
//! the registered set, in both directions, so the list can neither rot
//! nor fall behind a new command.

use coda::cli::{Args, VALUE_OPTS};
use std::collections::BTreeSet;

const MAIN_SRC: &str = include_str!("../src/main.rs");

/// Collect the string literal following every occurrence of `pat`
/// (call sites all use literal option names, enforced by the emptiness
/// assertions below).
fn literals_after(src: &str, pat: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut rest = src;
    while let Some(pos) = rest.find(pat) {
        rest = &rest[pos + pat.len()..];
        if let Some(end) = rest.find('"') {
            out.insert(rest[..end].to_string());
            rest = &rest[end..];
        }
    }
    out
}

fn consumed_value_opts() -> BTreeSet<String> {
    let mut opts = literals_after(MAIN_SRC, ".opt(\"");
    opts.extend(literals_after(MAIN_SRC, ".opt_parse(\""));
    opts
}

#[test]
fn every_value_option_main_consumes_is_registered() {
    let consumed = consumed_value_opts();
    assert!(
        consumed.len() >= 10,
        "the scan should find the CLI's option call sites, got {consumed:?}"
    );
    for opt in &consumed {
        assert!(
            VALUE_OPTS.contains(&opt.as_str()),
            "--{opt} is consumed by main.rs as a value option but is missing \
             from cli::VALUE_OPTS, so `--{opt} value` would silently parse as \
             a flag plus a stray positional"
        );
    }
}

#[test]
fn every_registered_value_option_is_consumed() {
    let consumed = consumed_value_opts();
    for opt in VALUE_OPTS {
        assert!(
            consumed.contains(*opt),
            "cli::VALUE_OPTS registers --{opt} but main.rs never reads it; \
             remove it or wire it up"
        );
    }
}

#[test]
fn flags_never_collide_with_value_options() {
    let flags = literals_after(MAIN_SRC, ".has_flag(\"");
    assert!(!flags.is_empty(), "the scan should find the CLI's flags");
    for f in &flags {
        assert!(
            !VALUE_OPTS.contains(&f.as_str()),
            "--{f} is read both as a flag and as a value option"
        );
    }
}

/// Missing positional arguments must surface as the usage error every
/// subcommand prints, never as a panic: scan each `.positional` access
/// and reject `.expect(`/`.unwrap(` in the same statement (the
/// historical `coda debug-pages` crash — `expect("bench")` on a missing
/// benchmark name).
#[test]
fn positional_access_never_panics_on_missing_args() {
    let mut rest = MAIN_SRC;
    let mut offset = 0usize;
    while let Some(pos) = rest.find(".positional") {
        let at = offset + pos;
        rest = &rest[pos + ".positional".len()..];
        offset = at + ".positional".len();
        let stmt_end = rest.find(';').unwrap_or(rest.len());
        let stmt = &rest[..stmt_end];
        let line = MAIN_SRC[..at].lines().count();
        assert!(
            !stmt.contains(".expect(") && !stmt.contains(".unwrap("),
            "main.rs line {line}: positional access panics on missing \
             arguments; return the subcommand's usage error instead:\n\
             .positional{stmt}"
        );
    }
}

/// End-to-end demonstration of the bug class: parsing `--opt value` with
/// the option unregistered turns it into flag + positional; with it
/// registered the value is captured. The registration test above is what
/// keeps every real option on the working side of this line.
#[test]
fn unregistered_value_option_degrades_to_flag() {
    let argv: Vec<String> = ["sweep", "PR", "--key", "remote_bw_gbs"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let broken = Args::parse(&argv, &[]).unwrap();
    assert!(broken.has_flag("key"), "unregistered option parses as flag");
    assert_eq!(broken.opt("key"), None);
    assert_eq!(broken.positional, vec!["PR", "remote_bw_gbs"]);
    let fixed = Args::parse(&argv, VALUE_OPTS).unwrap();
    assert_eq!(fixed.opt("key"), Some("remote_bw_gbs"));
    assert_eq!(fixed.positional, vec!["PR"]);
}
