//! Frozen copies of the pre-refactor event loops, kept as reference
//! implementations for the differential suite.
//!
//! `legacy_kernel_run` is the standalone `sim::KernelRun::run` body and
//! `legacy_run_mix` the standalone `multiprog::run_mix` body exactly as
//! they existed before the shared `engine` module was extracted (PR 2).
//! They are test-only oracles: the differential tests assert the unified
//! engine reproduces their cycle counts bit-for-bit for every mechanism
//! under both DRAM backends. Do not "improve" these — their value is
//! that they never change.

use coda::addr::{AddressMapper, Granularity, VirtualAddress};
use coda::config::SystemConfig;
use coda::gpu::Topology;
use coda::mem::{self, MemBackend, MemStats};
use coda::net::Interconnect;
use coda::sched::{Policy, Scheduler};
use coda::stats::{AccessStats, RunReport};
use coda::trace::KernelTrace;
use coda::vm::{Tlb, VirtualMemory};
use coda::workloads::BuiltWorkload;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey(u64, u64);

fn key(t: f64, seq: u64) -> TimeKey {
    debug_assert!(t >= 0.0);
    TimeKey(t.to_bits(), seq)
}

#[derive(Clone, Copy, Debug)]
struct SlotState {
    block_idx: u32,
    next_access: u32,
}

#[inline]
fn line_hash(x: u64) -> u64 {
    let mut z = x.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 31)
}

/// The pre-refactor single-kernel event loop, verbatim.
pub fn legacy_kernel_run(
    cfg: &SystemConfig,
    trace: &KernelTrace,
    vm: &mut VirtualMemory,
    obj_base: &[u64],
    policy: Policy,
    migrate_on_first_touch: bool,
) -> RunReport {
    let topo = Topology::new(cfg);
    let mapper = AddressMapper::new(cfg);
    let mut net = Interconnect::new(cfg);
    let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
    let mut tlbs: Vec<Tlb> = (0..topo.sms.len())
        .map(|_| Tlb::new(cfg.tlb_entries))
        .collect();
    let mut sched = Scheduler::new(policy, trace.num_blocks(), cfg);

    let mut id_to_idx = vec![u32::MAX; trace.num_blocks() as usize];
    for (i, b) in trace.blocks.iter().enumerate() {
        id_to_idx[b.block_id as usize] = i as u32;
    }

    let cyc = cfg.cycles_per_ns();
    let l2_threshold = (cfg.l2_hit_rate * u32::MAX as f64) as u64;
    let l2_hit_cycles = cfg.l2_hit_ns * cyc;
    let tlb_miss_cycles = cfg.tlb_miss_ns * cyc;
    let line = cfg.line_size;
    let page_shift = cfg.page_size.trailing_zeros();
    let mlp = cfg.mlp_per_block as u32;
    let compute = cfg.compute_cycles_per_access as f64;

    let mut stats = AccessStats::default();
    let mut migrated: u64 = 0;
    let mut migrated_pages: Vec<bool> = vec![false; vm.mapped_pages() as usize];
    let mut latency_sum = 0.0f64;
    let mut latency_n: u64 = 0;
    let mut end_time = 0.0f64;
    let mut seq: u64 = 0;

    let mut heap: BinaryHeap<Reverse<(TimeKey, u32, u32)>> = BinaryHeap::new();
    let slots_per_sm = cfg.blocks_per_sm;
    let mut slots: Vec<Option<SlotState>> = vec![None; topo.sms.len() * slots_per_sm];
    let mut sm_free: Vec<f64> = vec![0.0; topo.sms.len()];

    for slot in 0..slots_per_sm {
        for sm in &topo.sms {
            if let Some(bid) = sched.next_for(sm.stack) {
                let idx = id_to_idx[bid as usize];
                slots[sm.id * slots_per_sm + slot] = Some(SlotState {
                    block_idx: idx,
                    next_access: 0,
                });
                heap.push(Reverse((key(0.0, seq), sm.id as u32, slot as u32)));
                seq += 1;
            }
        }
    }

    while let Some(Reverse((tk, sm_id, slot_id))) = heap.pop() {
        let now = f64::from_bits(tk.0);
        let sm = topo.sms[sm_id as usize];
        let slot_key = sm_id as usize * slots_per_sm + slot_id as usize;
        let Some(state) = slots[slot_key] else { continue };
        let block = &trace.blocks[state.block_idx as usize];
        let begin = state.next_access as usize;
        let end = (begin + mlp as usize).min(block.accesses.len());

        let mut window_done = now;
        for a in &block.accesses[begin..end] {
            let vaddr = obj_base[a.obj as usize] + a.offset;
            let vline = vaddr / line;
            if line_hash(vline) & 0xFFFF_FFFF < l2_threshold {
                stats.l2_hits += 1;
                window_done = window_done.max(now + l2_hit_cycles);
                continue;
            }
            let vpn = vaddr >> page_shift;
            let mut t = now;
            let pte = match tlbs[sm.id].lookup(vpn) {
                Some(pte) => pte,
                None => {
                    t += tlb_miss_cycles;
                    let pte = vm
                        .pte_of(VirtualAddress(vaddr))
                        .expect("workload access beyond mapped object");
                    tlbs[sm.id].fill(vpn, pte);
                    pte
                }
            };
            let mut paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
            let mut gran = pte.granularity;
            if migrate_on_first_touch
                && gran == Granularity::Fgp
                && !migrated_pages[vpn as usize]
            {
                migrated_pages[vpn as usize] = true;
                if vm.migrate_to_cgp(VirtualAddress(vaddr), sm.stack).is_ok() {
                    migrated += 1;
                    let copy_bytes =
                        cfg.page_size * (cfg.num_stacks as u64 - 1) / cfg.num_stacks as u64;
                    t = net.remote_hop(t, (sm.stack + 1) % cfg.num_stacks, sm.stack, copy_bytes);
                    let pte = vm.pte_of(VirtualAddress(vaddr)).unwrap();
                    tlbs[sm.id].fill(vpn, pte);
                    paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                    gran = pte.granularity;
                }
            }
            let dst = mapper.stack_of(paddr, gran);
            let done = if dst == sm.stack {
                stats.local += 1;
                let t1 = net.local_hop(t, dst, line);
                stacks[dst].access(t1, paddr, line).done
            } else {
                stats.remote += 1;
                let t1 = net.remote_hop(t, sm.stack, dst, line);
                let t2 = stacks[dst].access(t1, paddr, line).done;
                net.remote_hop(t2, dst, sm.stack, line)
            };
            latency_sum += done - now;
            latency_n += 1;
            window_done = window_done.max(done);
        }
        let issued = (end - begin) as f64;
        let c_start = window_done.max(sm_free[sm.id]);
        let t_next = c_start + compute * issued;
        sm_free[sm.id] = t_next;
        end_time = end_time.max(t_next);

        if end < block.accesses.len() {
            slots[slot_key] = Some(SlotState {
                block_idx: state.block_idx,
                next_access: end as u32,
            });
            heap.push(Reverse((key(t_next, seq), sm_id, slot_id)));
            seq += 1;
        } else {
            match sched.next_for(sm.stack) {
                Some(bid) => {
                    slots[slot_key] = Some(SlotState {
                        block_idx: id_to_idx[bid as usize],
                        next_access: 0,
                    });
                    heap.push(Reverse((key(t_next, seq), sm_id, slot_id)));
                    seq += 1;
                }
                None => slots[slot_key] = None,
            }
        }
    }

    let tlb_hits: u64 = tlbs.iter().map(|t| t.hits).sum();
    let tlb_total: u64 = tlbs.iter().map(|t| t.hits + t.misses).sum();
    let row_hit_rate = {
        let rates: Vec<f64> = stacks.iter().map(|s| s.row_hit_rate()).collect();
        coda::stats::mean(&rates)
    };
    let mut mem_stats = MemStats::default();
    for s in &stacks {
        mem_stats.add(&s.stats());
    }
    RunReport {
        workload: trace.name.clone(),
        mechanism: String::new(),
        cycles: end_time,
        accesses: stats,
        stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
        remote_bytes: net.remote_bytes(),
        mean_mem_latency: if latency_n == 0 {
            0.0
        } else {
            latency_sum / latency_n as f64
        },
        tlb_hit_rate: if tlb_total == 0 {
            0.0
        } else {
            tlb_hits as f64 / tlb_total as f64
        },
        row_hit_rate,
        mem_backend: cfg.mem_backend.to_string(),
        bank_conflicts: mem_stats.row_conflicts,
        refresh_stalls: mem_stats.refresh_stalls,
        cgp_pages: 0,
        fgp_pages: 0,
        migrated_pages: migrated,
        ..Default::default()
    }
}

/// Placement style, mirroring `multiprog::MixPlacement` for the frozen
/// loop (kept separate so the oracle has zero dependence on the code
/// under test).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LegacyMixPlacement {
    FgpOnly,
    CgpLocal,
}

/// The pre-refactor multiprogrammed event loop, verbatim.
pub fn legacy_run_mix(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    placement: LegacyMixPlacement,
) -> coda::Result<(Vec<f64>, RunReport)> {
    assert!(apps.len() <= cfg.num_stacks);
    let topo = Topology::new(cfg);
    let mapper = AddressMapper::new(cfg);
    let mut net = Interconnect::new(cfg);
    let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
    let mut tlbs: Vec<Tlb> = (0..topo.sms.len())
        .map(|_| Tlb::new(cfg.tlb_entries))
        .collect();

    let mut vm = VirtualMemory::new(cfg);
    let mut app_bases: Vec<Vec<u64>> = Vec::new();
    for (home, app) in apps.iter().enumerate() {
        let mut bases = Vec::new();
        for obj in &app.trace.objects {
            let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
            let base = match placement {
                LegacyMixPlacement::FgpOnly => vm.map_fgp(pages)?.0,
                LegacyMixPlacement::CgpLocal => vm.map_cgp(pages, |_| home)?.0,
            };
            bases.push(base);
        }
        app_bases.push(bases);
    }

    let line = cfg.line_size;
    let cyc = cfg.cycles_per_ns();
    let page_shift = cfg.page_size.trailing_zeros();
    let tlb_miss_cycles = cfg.tlb_miss_ns * cyc;
    let mlp = cfg.mlp_per_block;
    let compute = cfg.compute_cycles_per_access as f64;

    let mut stats = AccessStats::default();
    let mut app_end = vec![0.0f64; apps.len()];
    let mut seq = 0u64;
    let mut heap: BinaryHeap<Reverse<(u64, u64, u32, u32, u32, u32)>> = BinaryHeap::new();
    let mut next_block: Vec<usize> = vec![0; apps.len()];
    let mut sm_free: Vec<f64> = vec![0.0; topo.sms.len()];

    for (app_idx, app) in apps.iter().enumerate() {
        let sms: Vec<usize> = topo.sms_of_stack(app_idx).map(|s| s.id).collect();
        let capacity = sms.len() * cfg.blocks_per_sm;
        for slot in 0..capacity {
            if next_block[app_idx] >= app.trace.blocks.len() {
                break;
            }
            let b = next_block[app_idx];
            next_block[app_idx] += 1;
            heap.push(Reverse((
                0f64.to_bits(),
                seq,
                app_idx as u32,
                b as u32,
                0,
                sms[slot % sms.len()] as u32,
            )));
            seq += 1;
        }
    }

    while let Some(Reverse((tb, _, app_idx, block_idx, next_acc, sm_id))) = heap.pop() {
        let now = f64::from_bits(tb);
        let app = apps[app_idx as usize];
        let home = app_idx as usize;
        let block = &app.trace.blocks[block_idx as usize];
        let begin = next_acc as usize;
        let endw = (begin + mlp).min(block.accesses.len());
        let mut window_done = now;
        for a in &block.accesses[begin..endw] {
            let vaddr = app_bases[home][a.obj as usize] + a.offset;
            let vpn = vaddr >> page_shift;
            let mut t = now;
            let pte = match tlbs[sm_id as usize].lookup(vpn) {
                Some(p) => p,
                None => {
                    t += tlb_miss_cycles;
                    let p = vm.pte_of(VirtualAddress(vaddr)).expect("mapped");
                    tlbs[sm_id as usize].fill(vpn, p);
                    p
                }
            };
            let paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
            let dst = mapper.stack_of(paddr, pte.granularity);
            let done = if dst == home {
                stats.local += 1;
                let t1 = net.local_hop(t, dst, line);
                stacks[dst].access(t1, paddr, line).done
            } else {
                stats.remote += 1;
                let t1 = net.remote_hop(t, home, dst, line);
                let t2 = stacks[dst].access(t1, paddr, line).done;
                net.remote_hop(t2, dst, home, line)
            };
            window_done = window_done.max(done);
        }
        let c_start = window_done.max(sm_free[sm_id as usize]);
        let t_next = c_start + compute * (endw - begin) as f64;
        sm_free[sm_id as usize] = t_next;
        app_end[home] = app_end[home].max(t_next);
        if endw < block.accesses.len() {
            heap.push(Reverse((
                t_next.to_bits(),
                seq,
                app_idx,
                block_idx,
                endw as u32,
                sm_id,
            )));
            seq += 1;
        } else if next_block[home] < app.trace.blocks.len() {
            let b = next_block[home];
            next_block[home] += 1;
            heap.push(Reverse((t_next.to_bits(), seq, app_idx, b as u32, 0, sm_id)));
            seq += 1;
        }
    }

    let mut mem_stats = MemStats::default();
    for s in &stacks {
        mem_stats.add(&s.stats());
    }
    let report = RunReport {
        workload: apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+"),
        mechanism: format!("{placement:?}"),
        cycles: app_end.iter().cloned().fold(0.0, f64::max),
        accesses: stats,
        stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
        remote_bytes: net.remote_bytes(),
        mem_backend: cfg.mem_backend.to_string(),
        bank_conflicts: mem_stats.row_conflicts,
        refresh_stalls: mem_stats.refresh_stalls,
        ..Default::default()
    };
    Ok((app_end, report))
}
