//! Differential suite: the unified `engine` must be **cycle-identical**
//! to the pre-refactor event loops (frozen in `legacy.rs`) for every
//! mechanism × workload × DRAM backend, and its numbers are additionally
//! locked into golden snapshots under `tests/golden/` so drift is caught
//! across machines and over time.
//!
//! Golden convention: a missing snapshot (or one whose first line is the
//! `# PENDING-RECORD` sentinel) is recorded on first run; afterwards any
//! mismatch fails. Regenerate intentionally with
//! `CODA_UPDATE_GOLDEN=1 cargo test --test differential`.

mod legacy;

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::{Coordinator, Mechanism};
use coda::multiprog::{run_mix, Mix, MixPlacement};
use coda::sim::{map_objects, KernelRun};
use coda::stats::RunReport;
use coda::workloads::suite;
use legacy::LegacyMixPlacement;
use std::fmt::Write as _;
use std::path::PathBuf;

const MECHS: [Mechanism; 7] = [
    Mechanism::FgpOnly,
    Mechanism::CgpOnly,
    Mechanism::CgpFta,
    Mechanism::MigrationFta,
    Mechanism::Coda,
    Mechanism::FgpAffinity,
    Mechanism::CodaStealing,
];

/// Representative slice of the workload suite: block-exclusive graph
/// (PR, DC), core-exclusive (KM, NN), and sharing (HS3D) behaviour.
const WORKLOADS: [&str; 5] = ["PR", "DC", "KM", "NN", "HS3D"];

fn cfg_for(backend: MemBackendKind) -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = backend;
    c
}

/// Field-by-field comparison of everything the legacy loop reported.
/// Cycle counts are compared bit-exactly: the refactor must not move a
/// single f64 operation.
fn assert_reports_identical(new: &RunReport, old: &RunReport, what: &str) {
    assert_eq!(new.cycles.to_bits(), old.cycles.to_bits(), "{what}: cycles");
    assert_eq!(new.accesses, old.accesses, "{what}: access counts");
    assert_eq!(new.stack_bytes, old.stack_bytes, "{what}: stack bytes");
    assert_eq!(new.remote_bytes, old.remote_bytes, "{what}: remote bytes");
    assert_eq!(new.bank_conflicts, old.bank_conflicts, "{what}: conflicts");
    assert_eq!(
        new.refresh_stalls, old.refresh_stalls,
        "{what}: refresh stalls"
    );
    assert_eq!(
        new.migrated_pages, old.migrated_pages,
        "{what}: migrated pages"
    );
}

#[test]
fn unified_engine_matches_legacy_kernel_loop() {
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        let cfg = cfg_for(backend);
        let coord = Coordinator::new(cfg.clone());
        for name in WORKLOADS {
            let wl = suite::build(name, &cfg).unwrap();
            for mech in MECHS {
                let plan = coord.plan_for(&wl, mech);
                let policy = mech.policy();
                let (mut vm_new, bases_new, _, _) =
                    map_objects(&cfg, &wl.trace, &plan).unwrap();
                let new = KernelRun {
                    cfg: &cfg,
                    trace: &wl.trace,
                    vm: &mut vm_new,
                    obj_base: &bases_new,
                    policy,
                    migrate_on_first_touch: plan.migrate_on_first_touch,
                }
                .run();
                let (mut vm_old, bases_old, _, _) =
                    map_objects(&cfg, &wl.trace, &plan).unwrap();
                // The frozen loop predates the VA newtype; hand it raw u64s.
                let bases_old: Vec<u64> = bases_old.iter().map(|b| b.0).collect();
                let old = legacy::legacy_kernel_run(
                    &cfg,
                    &wl.trace,
                    &mut vm_old,
                    &bases_old,
                    policy,
                    plan.migrate_on_first_touch,
                );
                let what = format!("{name}/{}/{}", mech.name(), cfg.mem_backend);
                assert_reports_identical(&new, &old, &what);
                assert_eq!(
                    new.mean_mem_latency.to_bits(),
                    old.mean_mem_latency.to_bits(),
                    "{what}: latency"
                );
                assert_eq!(
                    new.tlb_hit_rate.to_bits(),
                    old.tlb_hit_rate.to_bits(),
                    "{what}: tlb"
                );
                assert_eq!(
                    new.row_hit_rate.to_bits(),
                    old.row_hit_rate.to_bits(),
                    "{what}: row hit rate"
                );
            }
        }
    }
}

#[test]
fn unified_engine_matches_legacy_mix_loop() {
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        let cfg = cfg_for(backend);
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let c = suite::build("DC", &cfg).unwrap();
        let d = suite::build("HS", &cfg).unwrap();
        let mixes: [Vec<&coda::workloads::BuiltWorkload>; 2] =
            [vec![&a, &b, &c, &d], vec![&a, &c]];
        for apps in &mixes {
            for (placement, legacy_placement) in [
                (MixPlacement::FgpOnly, LegacyMixPlacement::FgpOnly),
                (MixPlacement::CgpLocal, LegacyMixPlacement::CgpLocal),
            ] {
                let mix = Mix { apps: apps.clone() };
                let (times_new, rep_new) = run_mix(&cfg, &mix, placement).unwrap();
                let (times_old, rep_old) =
                    legacy::legacy_run_mix(&cfg, apps, legacy_placement).unwrap();
                let what = format!(
                    "mix[{}]/{placement:?}/{}",
                    rep_new.workload, cfg.mem_backend
                );
                assert_eq!(
                    times_new.len(),
                    times_old.len(),
                    "{what}: app count"
                );
                for (i, (tn, to)) in times_new.iter().zip(&times_old).enumerate() {
                    assert_eq!(tn.to_bits(), to.to_bits(), "{what}: app {i} cycles");
                }
                assert_reports_identical(&rep_new, &rep_old, &what);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Golden cycle snapshots.
// ---------------------------------------------------------------------------

fn golden_path(file: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join(file)
}

/// Sentinel first line marking a committed-but-not-yet-recorded snapshot
/// (see `tests/golden_report.rs` for the rationale).
const PENDING: &str = "# PENDING-RECORD";

fn check_golden(file: &str, got: &str) {
    let path = golden_path(file);
    let update = std::env::var("CODA_UPDATE_GOLDEN").is_ok();
    match std::fs::read_to_string(&path) {
        Ok(want) if !update && !want.starts_with(PENDING) => {
            assert_eq!(
                got, want,
                "golden snapshot {file} drifted; if the change is intentional \
                 rerun with CODA_UPDATE_GOLDEN=1 and commit {path:?}"
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, got).unwrap();
            eprintln!("recorded golden snapshot at {path:?}");
        }
    }
}

fn render_cycles_snapshot(backend: MemBackendKind) -> String {
    let cfg = cfg_for(backend);
    let coord = Coordinator::new(cfg.clone());
    let mut out = format!(
        "# golden engine cycles ({} backend, test_small)\n\
         # workload | mechanism | cycles | local | remote | l2_hits\n",
        cfg.mem_backend
    );
    for name in WORKLOADS {
        let wl = suite::build(name, &cfg).unwrap();
        for mech in MECHS {
            let r = coord.run(&wl, mech).unwrap();
            writeln!(
                out,
                "{name} | {} | {} | {} | {} | {}",
                mech.name(),
                r.cycles,
                r.accesses.local,
                r.accesses.remote,
                r.accesses.l2_hits
            )
            .unwrap();
        }
    }
    // Multiprogrammed rows: the Fig 12 mix under both placements.
    let a = suite::build("NN", &cfg).unwrap();
    let b = suite::build("KM", &cfg).unwrap();
    let c = suite::build("DC", &cfg).unwrap();
    let d = suite::build("HS", &cfg).unwrap();
    for placement in [MixPlacement::FgpOnly, MixPlacement::CgpLocal] {
        let mix = Mix {
            apps: vec![&a, &b, &c, &d],
        };
        let (_, r) = run_mix(&cfg, &mix, placement).unwrap();
        writeln!(
            out,
            "mix:{} | {placement:?} | {} | {} | {} | {}",
            r.workload, r.cycles, r.accesses.local, r.accesses.remote, r.accesses.l2_hits
        )
        .unwrap();
    }
    out
}

#[test]
fn engine_cycles_match_golden_fixed() {
    let got = render_cycles_snapshot(MemBackendKind::FixedLatency);
    assert_eq!(
        got,
        render_cycles_snapshot(MemBackendKind::FixedLatency),
        "snapshot is not deterministic"
    );
    check_golden("engine_cycles_fixed.txt", &got);
}

#[test]
fn engine_cycles_match_golden_bank() {
    let got = render_cycles_snapshot(MemBackendKind::BankLevel);
    check_golden("engine_cycles_bank.txt", &got);
}

#[test]
fn engine_cycles_match_golden_cycle() {
    let got = render_cycles_snapshot(MemBackendKind::CycleAccurate);
    check_golden("engine_cycles_cycle.txt", &got);
}
