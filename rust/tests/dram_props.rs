//! Property tests (via `coda::proptest_lite`) for the cycle-accurate
//! DRAM backend and its `mem::protocol` legality checker:
//!
//! * FR-FCFS posted-write scheduling never starves a write past the
//!   aging cap, under randomized configs and access streams.
//! * Every command sequence the backend emits replays cleanly through a
//!   *fresh, independent* `protocol::Checker` — including streams that
//!   cross refresh windows and force watermark drains.
//! * The per-bank row state machine only transitions through legal
//!   closed → activated → precharged edges.
//! * The checker rejects hand-built violating sequences (a column
//!   command inside tRCD, a fifth ACT inside one tFAW window).

// Case generators mutate a default config; the lint's suggested struct
// literal obscures which knobs each property varies.
#![allow(clippy::field_reassign_with_default)]

use coda::config::{DramRowPolicy, MemBackendKind, SystemConfig};
use coda::mem::{protocol, MemBackendImpl};
use coda::proptest_lite::{run_prop, PropConfig};
use coda::rng::Rng;

fn cycle_cfg() -> SystemConfig {
    let mut c = SystemConfig::default();
    c.mem_backend = MemBackendKind::CycleAccurate;
    c
}

/// A randomized (addr, write, arrival) stream with non-decreasing
/// arrivals, the shape every property below drives the backend with.
fn gen_stream(rng: &mut Rng, len: usize) -> Vec<(u64, bool, f64)> {
    let mut now = 0.0;
    (0..len)
        .map(|_| {
            now += rng.below(50) as f64;
            (rng.below(1 << 24) & !127, rng.chance(0.4), now)
        })
        .collect()
}

/// FR-FCFS never starves a posted write past the aging cap: after any
/// access at time `now`, no queued write on *any* channel is older than
/// `dram_age_cap_ns` — the sweep retires overdue writes before the new
/// request is considered.
#[test]
fn prop_frfcfs_never_starves_past_aging_cap() {
    run_prop(
        PropConfig {
            cases: 24,
            seed: 0xD3A1,
        },
        |rng: &mut Rng| {
            let mut cfg = cycle_cfg();
            cfg.dram_wq_high = 4 + rng.below(28) as usize;
            cfg.dram_wq_low = rng.below(cfg.dram_wq_high as u64) as usize;
            cfg.dram_age_cap_ns = 100.0 + rng.below(1900) as f64;
            let stream = gen_stream(rng, 1500);
            (cfg, stream)
        },
        |(cfg, stream)| {
            cfg.validate().map_err(|e| e.to_string())?;
            let cap = cfg.dram_age_cap_ns * cfg.cycles_per_ns();
            let mut m = MemBackendImpl::new(cfg);
            for &(addr, write, now) in stream {
                m.access_rw(now, addr, 128, write);
                let MemBackendImpl::Cycle(inner) = &m else {
                    return Err("expected the cycle backend".into());
                };
                let age = inner.max_queued_write_age(now);
                if age > cap {
                    return Err(format!(
                        "write starved: age {age:.1} > cap {cap:.1} at t={now}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Every command sequence the backend emits is accepted by a fresh
/// checker built from the same `protocol::Params` — across row policies,
/// rank counts, refresh intervals and forced watermark drains. The
/// checker shares only the pure protocol-defining helpers with the
/// scheduler, so agreement here is two independent implementations of
/// the constraint set concurring, not one implementation vouching for
/// itself.
#[test]
fn prop_backend_commands_replay_clean_through_fresh_checker() {
    run_prop(
        PropConfig {
            cases: 24,
            seed: 0xD3A2,
        },
        |rng: &mut Rng| {
            let mut cfg = cycle_cfg();
            cfg.dram_row_policy = if rng.chance(0.5) {
                DramRowPolicy::Open
            } else {
                DramRowPolicy::Closed
            };
            cfg.dram_ranks_per_channel = 1 << rng.below(3); // 1, 2, 4
            // Small tREFI values force refresh-window crossings inside the
            // stream; tRFC stays well below every choice.
            cfg.dram_trefi_ns = *rng.choose(&[500.0, 1000.0, 3900.0]);
            cfg.dram_wq_high = 4 + rng.below(12) as usize;
            cfg.dram_wq_low = rng.below(cfg.dram_wq_high as u64) as usize;
            let stream = gen_stream(rng, 1200);
            (cfg, stream)
        },
        |(cfg, stream)| {
            cfg.validate().map_err(|e| e.to_string())?;
            let mut m = MemBackendImpl::new(cfg);
            if let MemBackendImpl::Cycle(inner) = &mut m {
                inner.enable_recording();
            }
            for &(addr, write, now) in stream {
                m.access_rw(now, addr, 128, write);
            }
            let MemBackendImpl::Cycle(inner) = &m else {
                return Err("expected the cycle backend".into());
            };
            let mut ck = protocol::Checker::new(inner.protocol_params());
            for cmd in inner.recorded() {
                ck.check(*cmd)
                    .map_err(|v| format!("checker rejected backend command: {v} ({cmd:?})"))?;
            }
            if inner.recorded().is_empty() {
                return Err("stream emitted no commands".into());
            }
            Ok(())
        },
    );
}

/// The per-bank row state machine only walks legal edges: ACT strictly on
/// a closed bank, PRE and column commands strictly on the open row, and
/// auto-precharge closing the bank. Refresh is pushed out of reach so the
/// explicit fold below is the complete state machine.
#[test]
fn prop_row_state_machine_walks_legal_edges() {
    run_prop(
        PropConfig {
            cases: 24,
            seed: 0xD3A3,
        },
        |rng: &mut Rng| {
            let mut cfg = cycle_cfg();
            cfg.dram_trefi_ns = 1e12; // no refresh: crossings close rows implicitly
            cfg.dram_row_policy = if rng.chance(0.5) {
                DramRowPolicy::Open
            } else {
                DramRowPolicy::Closed
            };
            let stream = gen_stream(rng, 1000);
            (cfg, stream)
        },
        |(cfg, stream)| {
            cfg.validate().map_err(|e| e.to_string())?;
            let mut m = MemBackendImpl::new(cfg);
            if let MemBackendImpl::Cycle(inner) = &mut m {
                inner.enable_recording();
            }
            for &(addr, write, now) in stream {
                m.access_rw(now, addr, 128, write);
            }
            let MemBackendImpl::Cycle(inner) = &m else {
                return Err("expected the cycle backend".into());
            };
            // open[(channel, bank)] = Some(row) while activated.
            let mut open = std::collections::HashMap::new();
            for cmd in inner.recorded() {
                let key = (cmd.channel, cmd.bank);
                let state = open.entry(key).or_insert(None::<u64>);
                match cmd.kind {
                    protocol::CmdKind::Act { row } => {
                        if state.is_some() {
                            return Err(format!("ACT on an activated bank: {cmd:?}"));
                        }
                        *state = Some(row);
                    }
                    protocol::CmdKind::Pre => {
                        if state.is_none() {
                            return Err(format!("PRE on a precharged bank: {cmd:?}"));
                        }
                        *state = None;
                    }
                    protocol::CmdKind::Rd { row, auto }
                    | protocol::CmdKind::Wr { row, auto } => {
                        if *state != Some(row) {
                            return Err(format!(
                                "column command to a row that is not open: {cmd:?}"
                            ));
                        }
                        if auto {
                            *state = None;
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// The checker rejects a column command issued before tRCD elapses.
#[test]
fn checker_rejects_column_inside_trcd() {
    let cfg = cycle_cfg();
    let p = protocol::Params::from_config(&cfg);
    assert!(p.trcd > 2.0 + p.cmd_gap, "default tRCD must leave room");
    let mut ck = protocol::Checker::new(p);
    ck.check(protocol::Command {
        time: 0.0,
        channel: 0,
        bank: 0,
        kind: protocol::CmdKind::Act { row: 7 },
    })
    .unwrap();
    let early = ck.check(protocol::Command {
        time: 2.0, // past the command-bus gap, well inside tRCD
        channel: 0,
        bank: 0,
        kind: protocol::CmdKind::Rd { row: 7, auto: false },
    });
    assert!(
        matches!(early, Err(protocol::Violation::ColBeforeTrcd { .. })),
        "expected a tRCD violation, got {early:?}"
    );
}

/// The checker rejects a fifth ACT inside one tFAW window (and flags the
/// other hand-built breakages along the way: ACT on an open bank, column
/// on a closed one).
#[test]
fn checker_rejects_fifth_act_in_tfaw_window() {
    let mut cfg = cycle_cfg();
    cfg.dram_tfaw_ns = 50.0; // widen tFAW past 4 * tRRD so it binds
    let p = protocol::Params::from_config(&cfg);
    let tfaw_start = 0.0;
    let mut ck = protocol::Checker::new(p);
    for i in 0..4 {
        ck.check(protocol::Command {
            time: tfaw_start + i as f64 * p.trrd,
            channel: 0,
            bank: i as usize,
            kind: protocol::CmdKind::Act { row: 1 },
        })
        .unwrap();
    }
    let fifth_at = tfaw_start + 4.0 * p.trrd;
    assert!(fifth_at < tfaw_start + p.tfaw, "fifth ACT must land in-window");
    let fifth = ck.check(protocol::Command {
        time: fifth_at,
        channel: 0,
        bank: 4,
        kind: protocol::CmdKind::Act { row: 1 },
    });
    assert!(
        matches!(fifth, Err(protocol::Violation::ActBeforeTfaw { .. })),
        "expected a tFAW violation, got {fifth:?}"
    );
    // A rejected command must not corrupt checker state: the same ACT
    // after the window reopens is legal.
    ck.check(protocol::Command {
        time: tfaw_start + p.tfaw,
        channel: 0,
        bank: 4,
        kind: protocol::CmdKind::Act { row: 1 },
    })
    .unwrap();

    // Companion hand-built breakages.
    let act_on_open = ck.check(protocol::Command {
        time: tfaw_start + p.tfaw + p.trrd,
        channel: 0,
        bank: 0,
        kind: protocol::CmdKind::Act { row: 9 },
    });
    assert!(matches!(
        act_on_open,
        Err(protocol::Violation::ActOnOpenBank { .. })
    ));
    let col_on_closed = ck.check(protocol::Command {
        time: tfaw_start + p.tfaw + 2.0 * p.trrd,
        channel: 0,
        bank: 15,
        kind: protocol::CmdKind::Wr { row: 0, auto: false },
    });
    assert!(matches!(
        col_on_closed,
        Err(protocol::Violation::ColOnClosedBank { .. })
    ));
}
