//! Golden-report snapshot: lock in today's numbers for `Coordinator::run`
//! across every `Mechanism` variant on a fixed-seed small workload, so a
//! future refactor that silently changes cycles, remote-access counts or
//! energy totals fails loudly instead of drifting.
//!
//! The snapshot lives at `tests/golden/coordinator_pr.txt`. On the first
//! run (file absent, or present with the `# PENDING-RECORD` sentinel
//! first line — the committed placeholder used when no Rust toolchain was
//! available to record real numbers) the test records it and passes;
//! afterwards any mismatch is a failure. Regenerate intentionally with
//! `CODA_UPDATE_GOLDEN=1 cargo test -q --test golden_report`.
//!
//! Robustness notes: the whole pipeline is integer/f64 arithmetic with
//! fixed seeds and no HashMap-order dependence in the simulated path, and
//! Rust's f64 `Display` prints the shortest round-trippable decimal, so
//! the rendered snapshot is stable across runs and platforms.

use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::energy::EnergyModel;
use coda::workloads::suite;
use std::fmt::Write as _;
use std::path::PathBuf;

const MECHS: [Mechanism; 7] = [
    Mechanism::FgpOnly,
    Mechanism::CgpOnly,
    Mechanism::CgpFta,
    Mechanism::MigrationFta,
    Mechanism::Coda,
    Mechanism::FgpAffinity,
    Mechanism::CodaStealing,
];

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("coordinator_pr.txt")
}

/// Render the snapshot: one line per mechanism with the report fields the
/// paper's conclusions rest on.
fn render_snapshot() -> String {
    let cfg = SystemConfig::test_small();
    let coord = Coordinator::new(cfg.clone());
    let wl = suite::build("PR", &cfg).unwrap();
    let em = EnergyModel::default();
    let mut out = String::from(
        "# golden snapshot: PR (test_small, fixed backend)\n\
         # mechanism | cycles | local | remote | l2_hits | migrated | energy_uj\n",
    );
    for mech in MECHS {
        let r = coord.run(&wl, mech).unwrap();
        let energy = em.estimate(&r, cfg.line_size).total_uj();
        writeln!(
            out,
            "{} | {} | {} | {} | {} | {} | {}",
            mech.name(),
            r.cycles,
            r.accesses.local,
            r.accesses.remote,
            r.accesses.l2_hits,
            r.migrated_pages,
            energy
        )
        .unwrap();
    }
    out
}

#[test]
fn coordinator_reports_match_golden_snapshot() {
    let path = golden_path();
    let got = render_snapshot();
    // Snapshots must at minimum be reproducible within one process.
    assert_eq!(got, render_snapshot(), "snapshot is not deterministic");

    let update = std::env::var("CODA_UPDATE_GOLDEN").is_ok();
    match std::fs::read_to_string(&path) {
        Ok(want) if !update && !want.starts_with("# PENDING-RECORD") => {
            assert_eq!(
                got, want,
                "golden snapshot drifted; if the change is intentional rerun \
                 with CODA_UPDATE_GOLDEN=1 and commit {path:?}"
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &got).unwrap();
            eprintln!("recorded golden snapshot at {path:?}");
        }
    }
}

/// The golden workload keeps the paper-shape orderings we rely on, so a
/// recorded snapshot can't silently encode a broken state: CODA must beat
/// FGP-Only on PR and not lose accesses.
#[test]
fn golden_workload_sanity() {
    let cfg = SystemConfig::test_small();
    let coord = Coordinator::new(cfg.clone());
    let wl = suite::build("PR", &cfg).unwrap();
    let total = wl.total_accesses();
    let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
    let coda = coord.run(&wl, Mechanism::Coda).unwrap();
    assert_eq!(fgp.accesses.ndp_total() + fgp.accesses.l2_hits, total);
    assert_eq!(coda.accesses.ndp_total() + coda.accesses.l2_hits, total);
    // No-degradation bound (§6.4); the stronger >1.05 speedup claims are
    // covered by the coordinator and backends tests on DC.
    assert!(coda.speedup_over(&fgp) > 0.95);
}
