//! Host + NDP concurrent-contention tests (CHoNDA-style co-location).
//!
//! The pre-engine sequential host sweep is frozen below as an oracle
//! (the same convention as `tests/differential/legacy.rs`): the engine's
//! [`coda::engine::HostStream`] path must reproduce it **bit-exactly**
//! under both DRAM backends. On top of that:
//!
//! * zero-intensity host traffic must leave the NDP run cycle-identical
//!   (bit-exact f64) to the `run_multi` baseline,
//! * host-alone `hostmix` must reproduce the legacy host-sweep cycles,
//! * higher host intensity must never make the NDP side faster, and
//! * the host-DDR split must divert traffic without perturbing NDP
//!   timing when it absorbs everything.

use coda::config::{MemBackendKind, SystemConfig};
use coda::host::run_host_sweep;
use coda::multiprog::{
    run_hostmix, run_multi, KernelLaunch, MixPlacement, MultiMix,
};
use coda::placement::{cgp_only_plan, PlacementPlan};
use coda::sched::{FairnessPolicy, Policy};
use coda::sim::map_objects;
use coda::workloads::{suite, BuiltWorkload};

/// Frozen copy of the pre-refactor `host::run_host_sweep` event loop
/// (PR 1 state), kept verbatim as the timing oracle. Do not modernize.
mod legacy {
    use coda::addr::{AddressMapper, VirtualAddress};
    use coda::config::SystemConfig;
    use coda::mem::{self, MemBackend, MemStats};
    use coda::net::Interconnect;
    use coda::stats::RunReport;
    use coda::trace::KernelTrace;
    use coda::vm::VirtualMemory;

    /// Outstanding host requests (an aggressive OoO core + MLP prefetchers).
    const HOST_MLP: usize = 64;

    pub fn legacy_host_sweep(
        cfg: &SystemConfig,
        trace: &KernelTrace,
        vm: &VirtualMemory,
        obj_base: &[VirtualAddress],
    ) -> RunReport {
        let mapper = AddressMapper::new(cfg);
        let mut net = Interconnect::new(cfg);
        let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
        let line = cfg.line_size;
        let mut host_accesses = 0u64;
        let mut window: Vec<f64> = Vec::with_capacity(HOST_MLP);
        let mut now = 0.0f64;
        let mut end = 0.0f64;
        for (obj, desc) in trace.objects.iter().enumerate() {
            let lines = desc.bytes.div_ceil(line);
            for l in 0..lines {
                let vaddr = obj_base[obj] + l * line;
                let (paddr, gran) = vm.translate(vaddr).expect("mapped");
                let stack = mapper.stack_of(paddr, gran);
                let t1 = net.host_hop(now, stack, line);
                let done = stacks[stack].access(t1, paddr.0, line).done;
                host_accesses += 1;
                window.push(done);
                end = end.max(done);
                if window.len() == HOST_MLP {
                    // The core stalls until the oldest window drains.
                    now = window.iter().cloned().fold(0.0, f64::max).max(now);
                    window.clear();
                }
            }
        }
        let mut mem_stats = MemStats::default();
        for s in &stacks {
            mem_stats.add(&s.stats());
        }
        RunReport {
            workload: trace.name.clone(),
            mechanism: "host".into(),
            cycles: end,
            accesses: coda::stats::AccessStats {
                host: host_accesses,
                ..Default::default()
            },
            stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
            remote_bytes: 0,
            mean_mem_latency: 0.0,
            tlb_hit_rate: 0.0,
            row_hit_rate: {
                let rates: Vec<f64> = stacks.iter().map(|s| s.row_hit_rate()).collect();
                coda::stats::mean(&rates)
            },
            mem_backend: cfg.mem_backend.to_string(),
            bank_conflicts: mem_stats.row_conflicts,
            refresh_stalls: mem_stats.refresh_stalls,
            cgp_pages: 0,
            fgp_pages: 0,
            migrated_pages: 0,
            ..Default::default()
        }
    }
}

fn cfg_for(backend: MemBackendKind) -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = backend;
    c
}

const BACKENDS: [MemBackendKind; 2] = [MemBackendKind::FixedLatency, MemBackendKind::BankLevel];

/// The engine-hosted sweep is bit-identical to the frozen sequential
/// loop: cycles, access counts, per-stack bytes, row behaviour — for
/// both interleavings under both DRAM backends.
#[test]
fn engine_host_sweep_matches_frozen_legacy() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let wl = suite::build("NN", &cfg).unwrap();
        let n = wl.trace.objects.len();
        let plans = [PlacementPlan::all_fgp(n), cgp_only_plan(n, &cfg)];
        for (pi, plan) in plans.iter().enumerate() {
            let (mut vm_new, bases_new, _, _) = map_objects(&cfg, &wl.trace, plan).unwrap();
            let new = run_host_sweep(&cfg, &wl.trace, &mut vm_new, &bases_new);
            let (vm_old, bases_old, _, _) = map_objects(&cfg, &wl.trace, plan).unwrap();
            let old = legacy::legacy_host_sweep(&cfg, &wl.trace, &vm_old, &bases_old);
            let what = format!("plan {pi}/{backend:?}");
            assert_eq!(new.cycles.to_bits(), old.cycles.to_bits(), "{what}: cycles");
            assert_eq!(new.accesses.host, old.accesses.host, "{what}: accesses");
            assert_eq!(new.accesses.ndp_total(), 0, "{what}: no NDP traffic");
            assert_eq!(new.stack_bytes, old.stack_bytes, "{what}: stack bytes");
            assert_eq!(
                new.row_hit_rate.to_bits(),
                old.row_hit_rate.to_bits(),
                "{what}: row hit rate"
            );
            assert_eq!(new.bank_conflicts, old.bank_conflicts, "{what}: conflicts");
            assert_eq!(
                new.refresh_stalls, old.refresh_stalls,
                "{what}: refresh stalls"
            );
            assert_eq!(new.mechanism, "host", "{what}");
        }
    }
}

/// Host-alone `hostmix` (no NDP kernels) reproduces the legacy sweep's
/// cycles bit-exactly: same FGP layout, same window walk, now merely
/// executed through the shared event heap.
#[test]
fn host_alone_hostmix_reproduces_legacy_sweep() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let h = suite::build("NN", &cfg).unwrap();
        let mix = MultiMix { launches: vec![] };
        let r = run_hostmix(
            &cfg,
            &mix,
            Some(&h),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        let (vm, bases, _, _) =
            map_objects(&cfg, &h.trace, &PlacementPlan::all_fgp(h.trace.objects.len())).unwrap();
        let old = legacy::legacy_host_sweep(&cfg, &h.trace, &vm, &bases);
        assert_eq!(
            r.cycles.to_bits(),
            old.cycles.to_bits(),
            "{backend:?}: host-alone hostmix must equal the legacy sweep"
        );
        assert_eq!(r.host_cycles.to_bits(), old.cycles.to_bits(), "{backend:?}");
        assert_eq!(r.accesses.host, old.accesses.host, "{backend:?}");
        assert_eq!(r.stack_bytes, old.stack_bytes, "{backend:?}");
        assert!((r.host_bw_share - 1.0).abs() < 1e-12, "{backend:?}");
    }
}

/// Zero-rate host traffic is a true no-op: with `host_mlp = 0` (and
/// likewise with no host workload at all) the NDP side of `hostmix` is
/// cycle-identical — bit-exact f64 — to the plain `run_multi` baseline,
/// under both DRAM backends.
#[test]
fn zero_intensity_host_is_cycle_identical_to_run_multi() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let apps: Vec<&BuiltWorkload> = vec![&a, &b];
        let mk_mix = || MultiMix {
            launches: apps
                .iter()
                .map(|&app| KernelLaunch { app, arrival: 0.0 })
                .collect(),
        };
        let base = run_multi(
            &cfg,
            &mk_mix(),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        let mut zero = cfg.clone();
        zero.host_mlp = 0;
        let host = suite::build("DC", &zero).unwrap();
        for host_arg in [Some(&*host), None] {
            let r = run_hostmix(
                &zero,
                &mk_mix(),
                host_arg,
                MixPlacement::CgpLocal,
                Policy::Affinity,
                FairnessPolicy::Fcfs,
            )
            .unwrap();
            let what = format!("{backend:?}/host={:?}", host_arg.map(|h| h.name));
            assert_eq!(r.cycles.to_bits(), base.cycles.to_bits(), "{what}: cycles");
            assert_eq!(r.app_cycles.len(), base.app_cycles.len(), "{what}");
            for (i, (x, y)) in r.app_cycles.iter().zip(&base.app_cycles).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: app {i} cycles");
            }
            assert_eq!(r.accesses, base.accesses, "{what}: access counts");
            assert_eq!(r.accesses.host_total(), 0, "{what}: no host traffic");
            assert_eq!(r.host_cycles, 0.0, "{what}");
            assert_eq!(r.host_bw_share, 0.0, "{what}");
        }
        // host_passes = 0 disables traffic the same way.
        let mut nopass = cfg.clone();
        nopass.host_passes = 0;
        let r = run_hostmix(
            &nopass,
            &mk_mix(),
            Some(&host),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        assert_eq!(r.cycles.to_bits(), base.cycles.to_bits(), "{backend:?}");
    }
}

/// Concurrent host traffic must cost both sides something: the NDP mix
/// slows down versus running host-free, the host slows down versus
/// streaming alone, the bandwidth split names both parties, and the host
/// ports record queuing. The NDP side is made memory-bound
/// (`compute_cycles_per_access = 0`) so DRAM-channel interference cannot
/// hide behind SM compute serialization.
#[test]
fn contention_slows_both_sides_and_is_accounted() {
    let mut cfg = cfg_for(MemBackendKind::FixedLatency);
    cfg.host_passes = 4; // sustain host pressure across the NDP run
    cfg.compute_cycles_per_access = 0; // memory-bound NDP side
    let a = suite::build("NN", &cfg).unwrap();
    let h = suite::build("KM", &cfg).unwrap();
    let mix = MultiMix {
        launches: vec![KernelLaunch {
            app: &a,
            arrival: 0.0,
        }],
    };
    let r = run_hostmix(
        &cfg,
        &mix,
        Some(&h),
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    )
    .unwrap();
    assert!(
        r.ndp_slowdown > 1.0,
        "host traffic must slow the NDP side: {}",
        r.ndp_slowdown
    );
    assert!(
        r.host_slowdown > 1.0,
        "NDP traffic must slow the host: {}",
        r.host_slowdown
    );
    assert!(
        r.app_slowdown.iter().all(|&s| s >= 1.0),
        "per-app host interference: {:?}",
        r.app_slowdown
    );
    assert!(
        r.host_bw_share > 0.0 && r.host_bw_share < 1.0,
        "both sources must own part of the DRAM bytes: {}",
        r.host_bw_share
    );
    assert!(
        r.host_port_stalls > 0,
        "a 64-deep window over 4 ports must queue somewhere"
    );
    // Byte accounting closes: host port bytes + NDP bytes = stack bytes.
    let total: u64 = r.stack_bytes.iter().sum();
    let ndp_bytes = r.accesses.ndp_total() * cfg.line_size;
    assert_eq!(r.host_bytes + ndp_bytes, total, "byte accounting");
    assert_eq!(r.host_bytes, r.accesses.host * cfg.line_size);
}

/// Contention monotonicity: raising the host-intensity knob (requests in
/// flight) never makes the NDP kernel finish earlier.
///
/// Host pages are distinct physical pages from the NDP's, so host
/// traffic can only close the NDP's DRAM rows, occupy its channels, or
/// queue ahead of it — every mechanism is harmful. Two sources of slack
/// remain, and the tolerances reflect them: intensities above zero can
/// tie (a gentler window drains the same total host bytes over a longer
/// period, which can interfere with the NDP run by a near-identical
/// amount), and contention-shifted retire order can reshuffle block→SM
/// assignment by a hair. Zero → full intensity must be strictly harmful.
#[test]
fn host_intensity_never_speeds_up_ndp() {
    for backend in BACKENDS {
        let mut cycles = Vec::new();
        for mlp in [0usize, 8, 64] {
            let mut cfg = cfg_for(backend);
            cfg.host_mlp = mlp;
            cfg.host_passes = 4;
            cfg.compute_cycles_per_access = 0; // memory-bound NDP side
            let a = suite::build("NN", &cfg).unwrap();
            let h = suite::build("KM", &cfg).unwrap();
            let mix = MultiMix {
                launches: vec![KernelLaunch {
                    app: &a,
                    arrival: 0.0,
                }],
            };
            let r = run_hostmix(
                &cfg,
                &mix,
                Some(&h),
                MixPlacement::CgpLocal,
                Policy::Affinity,
                FairnessPolicy::Fcfs,
            )
            .unwrap();
            cycles.push(r.app_cycles[0]);
        }
        for w in cycles.windows(2) {
            assert!(
                w[1] >= w[0] * (1.0 - 1e-3),
                "{backend:?}: more host traffic decreased NDP cycles: {cycles:?}"
            );
        }
        assert!(
            cycles[2] > cycles[0] * 1.001,
            "{backend:?}: full host intensity must visibly cost the NDP side: {cycles:?}"
        );
    }
}

/// Host-DDR split: with `host_ddr_fraction = 1.0` every host line is
/// served by host-local DDR — the stacks, host ports and therefore the
/// NDP side are untouched (bit-exact vs a host-free run). A 0.5 split
/// sends traffic both ways and still serves every line exactly once.
#[test]
fn host_ddr_absorbs_traffic_without_touching_stacks() {
    let mk = |ddr_fraction: f64, mlp: usize| {
        let mut cfg = cfg_for(MemBackendKind::FixedLatency);
        cfg.host_ddr_fraction = ddr_fraction;
        cfg.host_mlp = mlp;
        cfg
    };
    let cfg = mk(1.0, 64);
    let a = suite::build("NN", &cfg).unwrap();
    let h = suite::build("KM", &cfg).unwrap();
    let lines: u64 = h
        .trace
        .objects
        .iter()
        .map(|o| o.bytes.div_ceil(cfg.line_size))
        .sum();
    let mix = || MultiMix {
        launches: vec![KernelLaunch {
            app: &a,
            arrival: 0.0,
        }],
    };
    let run = |cfg: &SystemConfig, host: Option<&BuiltWorkload>| {
        run_hostmix(
            cfg,
            &mix(),
            host,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap()
    };
    let all_ddr = run(&cfg, Some(&h));
    assert_eq!(all_ddr.accesses.host, 0, "no host line may reach a stack");
    assert_eq!(all_ddr.accesses.host_ddr, lines);
    assert_eq!(all_ddr.host_bytes, 0);
    assert_eq!(all_ddr.host_ddr_bytes, lines * cfg.line_size);
    assert_eq!(all_ddr.host_bw_share, 0.0);
    assert!(all_ddr.host_cycles > 0.0);
    let baseline = run(&mk(0.0, 0), None);
    assert_eq!(
        all_ddr.app_cycles[0].to_bits(),
        baseline.app_cycles[0].to_bits(),
        "DDR-only host traffic must leave NDP timing bit-identical"
    );
    assert!(
        (all_ddr.ndp_slowdown - 1.0).abs() < 1e-12,
        "ndp slowdown {}",
        all_ddr.ndp_slowdown
    );

    let half = run(&mk(0.5, 64), Some(&h));
    assert_eq!(half.accesses.host + half.accesses.host_ddr, lines);
    assert!(half.accesses.host > 0 && half.accesses.host_ddr > 0);
    assert!(half.host_bw_share > 0.0 && half.host_bw_share < 1.0);
}

/// Determinism across repeated co-runs (the heap interleaving of host
/// and NDP events is fully ordered by (time, seq)).
#[test]
fn hostmix_is_deterministic() {
    let cfg = cfg_for(MemBackendKind::BankLevel);
    let a = suite::build("NN", &cfg).unwrap();
    let h = suite::build("KM", &cfg).unwrap();
    let run = || {
        let mix = MultiMix {
            launches: vec![KernelLaunch {
                app: &a,
                arrival: 0.0,
            }],
        };
        run_hostmix(
            &cfg,
            &mix,
            Some(&h),
            MixPlacement::FgpOnly,
            Policy::Baseline,
            FairnessPolicy::Fcfs,
        )
        .unwrap()
    };
    let x = run();
    let y = run();
    assert_eq!(x.cycles.to_bits(), y.cycles.to_bits());
    assert_eq!(x.host_cycles.to_bits(), y.host_cycles.to_bits());
    assert_eq!(x.accesses, y.accesses);
    assert_eq!(x.host_port_stalls, y.host_port_stalls);
}
