//! Integration tests: cross-module invariants (property-based via
//! `coda::proptest_lite`), end-to-end coordinator behaviour, and the
//! PJRT runtime round-trip against the AOT artifacts (requires
//! `make artifacts`; the Makefile orders that before `cargo test`).

// Case generators mutate a default config; the lint's suggested struct
// literal obscures which knobs each property varies.
#![allow(clippy::field_reassign_with_default)]

use coda::addr::{AddressMapper, Granularity};
use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::placement::{eq2_chunk_size, eq3_stack_of};
use coda::proptest_lite::{run_prop, usize_in, PropConfig};
use coda::rng::Rng;
use coda::sched::affinity_stack;
use coda::vm::{PhysAllocator, VirtualMemory};
use coda::workloads::suite;

fn small_cfg() -> SystemConfig {
    SystemConfig::test_small()
}

// ---------------------------------------------------------------------------
// Property: the central CODA invariant. For any (stacks, blocks_per_stack,
// B), Eq-2/3 placement routes every block's footprint to its Eq-1 affinity
// stack (up to the page-rounding skew at chunk boundaries).
// ---------------------------------------------------------------------------
#[test]
fn prop_eq23_placement_matches_affinity() {
    run_prop(
        PropConfig {
            cases: 64,
            seed: 0xA11,
        },
        |rng: &mut Rng| {
            let mut cfg = SystemConfig::default();
            cfg.num_stacks = 1 << rng.range(1, 4); // 2..8
            cfg.fgp_interleave = 128;
            cfg.sms_per_stack = usize_in(rng, 1, 5);
            cfg.blocks_per_sm = usize_in(rng, 1, 9);
            let b_bytes = rng.range(64, 64 * 1024);
            (cfg, b_bytes)
        },
        |(cfg, b_bytes)| {
            let chunk = eq2_chunk_size(*b_bytes, cfg);
            // Chunk must be page-aligned.
            if chunk % cfg.page_size != 0 {
                return Err(format!("chunk {chunk} not page multiple"));
            }
            // When B*N divides the chunk exactly, the mapping is exact.
            let window = b_bytes * cfg.blocks_per_stack() as u64;
            if chunk == window {
                for block in (0..2000u32).step_by(7) {
                    let aff = affinity_stack(block, cfg);
                    let byte = block as u64 * b_bytes; // first byte of block's slice
                    let got = eq3_stack_of(byte, chunk, cfg.num_stacks);
                    if got != aff {
                        return Err(format!("block {block}: stack {got} != affinity {aff}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Property: page-group space conservation. Any interleaving of FGP/CGP
// allocations and frees never double-assigns a physical page, and a CGP
// allocation always lands on the requested stack.
// ---------------------------------------------------------------------------
#[test]
fn prop_allocator_never_double_allocates() {
    run_prop(
        PropConfig {
            cases: 48,
            seed: 0xA110C,
        },
        |rng: &mut Rng| {
            // A random alloc/free script.
            let ops: Vec<(u8, usize)> = (0..200)
                .map(|_| (rng.below(3) as u8, rng.below(4) as usize))
                .collect();
            ops
        },
        |ops| {
            let cfg = small_cfg();
            let mapper = AddressMapper::new(&cfg);
            let mut alloc = PhysAllocator::new(&cfg);
            let mut live: Vec<u64> = Vec::new();
            for (op, stack) in ops {
                match op {
                    0 => {
                        let p = alloc.alloc_fgp().map_err(|e| e.to_string())?;
                        if live.contains(&p) {
                            return Err(format!("double allocation of {p}"));
                        }
                        live.push(p);
                    }
                    1 => {
                        let p = alloc.alloc_cgp(*stack).map_err(|e| e.to_string())?;
                        if live.contains(&p) {
                            return Err(format!("double allocation of {p}"));
                        }
                        if mapper.stack_of_ppn_cgp(p) != *stack {
                            return Err(format!("cgp page {p} on wrong stack"));
                        }
                        live.push(p);
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = (*stack * 7919) % live.len();
                            let p = live.swap_remove(idx);
                            alloc.free(p);
                        }
                    }
                }
            }
            if alloc.pages_allocated() != live.len() as u64 {
                return Err("allocation count mismatch".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Property: translation consistency. Any mix of FGP/CGP mappings
// translates every byte to a unique physical line, and CGP pages are fully
// stack-resident.
// ---------------------------------------------------------------------------
#[test]
fn prop_vm_translation_consistent() {
    run_prop(
        PropConfig {
            cases: 32,
            seed: 0x7141,
        },
        |rng: &mut Rng| {
            let segs: Vec<(bool, u64, usize)> = (0..12)
                .map(|_| (rng.chance(0.5), rng.range(1, 6), rng.below(4) as usize))
                .collect();
            segs
        },
        |segs| {
            let cfg = small_cfg();
            let mapper = AddressMapper::new(&cfg);
            let mut vm = VirtualMemory::new(&cfg);
            let mut seen = std::collections::HashSet::new();
            for (is_cgp, pages, stack) in segs {
                let base = if *is_cgp {
                    vm.map_cgp(*pages, |_| *stack).map_err(|e| e.to_string())?
                } else {
                    vm.map_fgp(*pages).map_err(|e| e.to_string())?
                };
                for pg in 0..*pages {
                    let vaddr = base + pg * cfg.page_size;
                    let (paddr, g) = vm.translate(vaddr).ok_or("unmapped")?;
                    if !seen.insert(paddr.0 >> 12) {
                        return Err(format!("physical page {:#x} mapped twice", paddr.0));
                    }
                    if *is_cgp {
                        if g != Granularity::Cgp {
                            return Err("granularity bit lost".into());
                        }
                        for off in [0u64, 128, 4095] {
                            let (p, g) = vm.translate(vaddr + off).ok_or("unmapped")?;
                            if mapper.stack_of(p, g) != *stack {
                                return Err("CGP page split across stacks".into());
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Coordinator invariants across the full suite (scaled-down runs).
// ---------------------------------------------------------------------------
#[test]
fn access_conservation_across_mechanisms() {
    let cfg = small_cfg();
    let coord = Coordinator::new(cfg.clone());
    for name in ["PR", "KM", "TC"] {
        let wl = suite::build(name, &cfg).unwrap();
        let total = wl.total_accesses();
        for mech in [
            Mechanism::FgpOnly,
            Mechanism::CgpOnly,
            Mechanism::CgpFta,
            Mechanism::Coda,
        ] {
            let r = coord.run(&wl, mech).unwrap();
            assert_eq!(
                r.accesses.ndp_total() + r.accesses.l2_hits,
                total,
                "{name}/{}",
                mech.name()
            );
        }
    }
}

#[test]
fn coda_never_degrades_any_benchmark() {
    // §6.4: "CODA does not degrade performance in any case."
    let cfg = small_cfg();
    let coord = Coordinator::new(cfg.clone());
    for (name, _) in suite::ALL {
        if *name == "SAD" {
            continue; // the known Fig-14 load-imbalance exception
        }
        let wl = suite::build(name, &cfg).unwrap();
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        let s = coda.speedup_over(&fgp);
        assert!(s > 0.93, "{name}: CODA regressed to {s:.3}x");
    }
}

#[test]
fn coda_reduces_remote_suitewide() {
    let cfg = small_cfg();
    let coord = Coordinator::new(cfg.clone());
    let mut reductions = Vec::new();
    for (name, _) in suite::ALL {
        let wl = suite::build(name, &cfg).unwrap();
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        reductions.push(coda.remote_reduction_over(&fgp));
    }
    let mean = coda::stats::mean(&reductions);
    assert!(
        mean > 0.3,
        "suite-wide mean remote reduction {mean:.2} too small (paper: 0.38)"
    );
}

// ---------------------------------------------------------------------------
// PJRT runtime round-trip. These tests need the `xla` feature AND the AOT
// artifacts (`make artifacts`); without either they skip with a note so the
// default build's tier-1 stays green.
// ---------------------------------------------------------------------------

/// Open the runtime and load one artifact, or return `None` (skip) with an
/// explanation when PJRT execution is unavailable in this build.
fn load_artifact(name: &str) -> Option<(coda::runtime::Runtime, String)> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = match coda::runtime::Runtime::new(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT test: {e:#}");
            return None;
        }
    };
    if !rt.artifact_exists(name) {
        eprintln!("skipping PJRT test: artifact {name} not built (run `make artifacts`)");
        return None;
    }
    Some((rt, name.to_string()))
}

#[test]
fn pjrt_pagerank_matches_rust_oracle() {
    let Some((mut rt, name)) = load_artifact("pagerank_update") else {
        return;
    };
    const V: usize = 8192;
    const K: usize = 16;
    let mut rng = Rng::new(99);
    let mut ranks = vec![0.0f32; V];
    for r in ranks.iter_mut() {
        *r = rng.f32();
    }
    let sum: f32 = ranks.iter().sum();
    for r in ranks.iter_mut() {
        *r /= sum;
    }
    let inv_deg: Vec<f32> = (0..V).map(|_| 1.0 / rng.range(1, K as u64 + 1) as f32).collect();
    let nbr: Vec<i32> = (0..V * K).map(|_| rng.below(V as u64) as i32).collect();
    let mask: Vec<f32> = (0..V * K)
        .map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 })
        .collect();
    let exe = match rt.load(&name) {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("skipping PJRT test: {e:#}");
            return;
        }
    };
    let got = coda::runtime::run_pagerank(exe, &ranks, &inv_deg, &nbr, &mask, V, K).unwrap();
    // Rust oracle.
    let d = 0.85f32;
    for v in 0..V {
        let mut acc = 0.0f32;
        for k in 0..K {
            let n = nbr[v * K + k] as usize;
            acc += ranks[n] * inv_deg[n] * mask[v * K + k];
        }
        let want = (1.0 - d) / V as f32 + d * acc;
        assert!(
            (got[v] - want).abs() < 1e-5,
            "vertex {v}: {} vs {want}",
            got[v]
        );
    }
}

#[test]
fn pjrt_kmeans_assign_matches_oracle() {
    let Some((mut rt, name)) = load_artifact("kmeans_assign") else {
        return;
    };
    const N: usize = 4096;
    const F: usize = 8;
    const K: usize = 8;
    let mut rng = Rng::new(5);
    let points: Vec<f32> = (0..N * F).map(|_| rng.normal() as f32).collect();
    let centroids: Vec<f32> = (0..K * F).map(|_| rng.normal() as f32).collect();
    let exe = match rt.load(&name) {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("skipping PJRT test: {e:#}");
            return;
        }
    };
    let out = exe
        .run(&[
            coda::runtime::Arg::F32(&points, &[N, F]),
            coda::runtime::Arg::F32(&centroids, &[K, F]),
        ])
        .unwrap();
    let assign = &out[0];
    // Oracle assignment.
    for i in (0..N).step_by(37) {
        let mut best = (f32::INFINITY, 0usize);
        for c in 0..K {
            let mut d = 0.0f32;
            for f in 0..F {
                let diff = points[i * F + f] - centroids[c * F + f];
                d += diff * diff;
            }
            if d < best.0 {
                best = (d, c);
            }
        }
        assert_eq!(assign[i] as usize, best.1, "point {i}");
    }
}

#[test]
fn pjrt_hotspot_matches_oracle() {
    let Some((mut rt, name)) = load_artifact("hotspot_step") else {
        return;
    };
    const H: usize = 128;
    const W: usize = 128;
    let mut rng = Rng::new(17);
    let temp: Vec<f32> = (0..H * W).map(|_| rng.f32() * 80.0).collect();
    let power: Vec<f32> = (0..H * W).map(|_| rng.f32()).collect();
    let exe = match rt.load(&name) {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("skipping PJRT test: {e:#}");
            return;
        }
    };
    let out = exe
        .run(&[
            coda::runtime::Arg::F32(&temp, &[H, W]),
            coda::runtime::Arg::F32(&power, &[H, W]),
        ])
        .unwrap();
    let got = &out[0];
    let (alpha, beta) = (0.1f32, 0.05f32);
    let at = |r: isize, c: isize| {
        let r = r.clamp(0, H as isize - 1) as usize;
        let c = c.clamp(0, W as isize - 1) as usize;
        temp[r * W + c]
    };
    for r in (0..H).step_by(13) {
        for c in (0..W).step_by(11) {
            let (ri, ci) = (r as isize, c as isize);
            let want = at(ri, ci)
                + alpha
                    * (at(ri - 1, ci) + at(ri + 1, ci) + at(ri, ci - 1) + at(ri, ci + 1)
                        - 4.0 * at(ri, ci))
                + beta * power[r * W + c];
            assert!(
                (got[r * W + c] - want).abs() < 1e-4,
                "({r},{c}): {} vs {want}",
                got[r * W + c]
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Determinism across the public surface.
// ---------------------------------------------------------------------------
#[test]
fn full_pipeline_is_deterministic() {
    let cfg = small_cfg();
    let coord = Coordinator::new(cfg.clone());
    let wl1 = suite::build("SPMV", &cfg).unwrap();
    let wl2 = suite::build("SPMV", &cfg).unwrap();
    let r1 = coord.run(&wl1, Mechanism::Coda).unwrap();
    let r2 = coord.run(&wl2, Mechanism::Coda).unwrap();
    assert_eq!(r1.cycles, r2.cycles);
    assert_eq!(r1.accesses, r2.accesses);
    assert_eq!(r1.stack_bytes, r2.stack_bytes);
}

#[test]
fn trace_record_replay_preserves_results() {
    let cfg = small_cfg();
    let wl = suite::build("NN", &cfg).unwrap();
    let mut buf = Vec::new();
    coda::trace::write_trace(&mut buf, &wl.trace).unwrap();
    let replayed = coda::trace::read_trace(&mut buf.as_slice()).unwrap();
    let coord = Coordinator::new(cfg.clone());
    let r1 = coord.run(&wl, Mechanism::FgpOnly).unwrap();
    let wl2 = coda::workloads::BuiltWorkload {
        name: "NN",
        category: wl.category,
        trace: replayed,
        ir: wl.ir.clone(),
        env: coda::analysis::ParamEnv::new(256),
    };
    let r2 = coord.run(&wl2, Mechanism::FgpOnly).unwrap();
    assert_eq!(r1.cycles, r2.cycles);
    assert_eq!(r1.accesses, r2.accesses);
}
