//! Multi-kernel scheduling tests: the N-apps-equals-N-sequential-runs
//! equivalence property when contention is disabled, oversubscribed
//! mixes (more kernels than stacks), staggered arrivals, fairness
//! policies, and the multiprogrammed placement expectations under both
//! DRAM backends.

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::Coordinator;
use coda::multiprog::{run_mix, run_multi, KernelLaunch, Mix, MixPlacement, MultiMix};
use coda::sched::{FairnessPolicy, Policy};
use coda::workloads::suite;
use coda::workloads::BuiltWorkload;

fn cfg_for(backend: MemBackendKind) -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = backend;
    c
}

fn build_apps(names: &[&str], cfg: &SystemConfig) -> Vec<Box<BuiltWorkload>> {
    names.iter().map(|n| suite::build(n, cfg).unwrap()).collect()
}

fn launches_at<'a>(
    apps: &'a [Box<BuiltWorkload>],
    arrival_of: impl Fn(usize) -> f64,
) -> MultiMix<'a> {
    MultiMix {
        launches: apps
            .iter()
            .enumerate()
            .map(|(i, a)| KernelLaunch {
                app: a,
                arrival: arrival_of(i),
            })
            .collect(),
    }
}

/// The headline equivalence property: with contention disabled — one app
/// per stack, CGP-local placement (disjoint footprints, no remote
/// traffic), affinity scheduling (disjoint SMs) — running N apps
/// together is **bit-identical** to running each alone. `run_multi`
/// computes the run-alone baselines internally over the same physical
/// layout, so every per-app slowdown must be exactly 1.0 and weighted
/// speedup exactly N, under both DRAM backends (the bank-level model's
/// refresh windows are absolute-time-based, so even they can't leak
/// across disjoint stacks).
#[test]
fn n_apps_equal_n_sequential_runs_without_contention() {
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        let cfg = cfg_for(backend);
        let apps = build_apps(&["NN", "KM", "DC", "HS"], &cfg);
        let mix = launches_at(&apps, |_| 0.0);
        let r = run_multi(
            &cfg,
            &mix,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        assert_eq!(r.accesses.remote, 0, "{backend:?}: CGP-local must be local");
        for (i, &s) in r.app_slowdown.iter().enumerate() {
            assert_eq!(
                s, 1.0,
                "{backend:?}: app {i} must be unaffected by co-runners, slowdown {s}"
            );
        }
        assert_eq!(
            r.weighted_speedup, 4.0,
            "{backend:?}: weighted speedup must be exactly N"
        );
    }
}

/// The converse: under FGP-Only placement the apps share every stack's
/// DRAM and the remote links, so co-running must cost someone something.
#[test]
fn fgp_contention_shows_up_as_slowdown() {
    let cfg = cfg_for(MemBackendKind::FixedLatency);
    let apps = build_apps(&["NN", "KM", "DC", "HS"], &cfg);
    let mix = launches_at(&apps, |_| 0.0);
    let r = run_multi(
        &cfg,
        &mix,
        MixPlacement::FgpOnly,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    )
    .unwrap();
    assert!(r.accesses.remote > 0);
    assert!(
        r.app_slowdown.iter().any(|&s| s > 1.01),
        "shared remote links must slow someone down: {:?}",
        r.app_slowdown
    );
    assert!(
        r.weighted_speedup < 4.0 - 1e-6,
        "weighted speedup {} must reflect contention",
        r.weighted_speedup
    );
}

/// Staggering far enough apart removes all SM/time overlap, so the
/// no-contention equivalence holds even through the staggered path
/// (arrival bookkeeping, idle-slot wakeups) when footprints are
/// stack-disjoint.
#[test]
fn staggered_disjoint_apps_still_equal_solo_runs() {
    let cfg = cfg_for(MemBackendKind::FixedLatency);
    let apps = build_apps(&["NN", "DC"], &cfg);
    let mix = launches_at(&apps, |i| i as f64 * 1e7);
    let r = run_multi(
        &cfg,
        &mix,
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    )
    .unwrap();
    // App 0 (arrival 0) matches its solo run bit-exactly; app 1's whole
    // timeline is shifted by its arrival offset, and f64 addition is not
    // shift-invariant, so it matches only to rounding error.
    assert_eq!(r.app_slowdown[0], 1.0, "app 0 runs exactly as if alone");
    for (i, &s) in r.app_slowdown.iter().enumerate() {
        assert!((s - 1.0).abs() < 1e-6, "staggered app {i} slowdown {s}");
    }
    // Response times are measured from each app's arrival, not t=0.
    let total: u64 = apps.iter().map(|a| a.total_accesses()).sum();
    assert_eq!(r.accesses.ndp_total(), total);
    assert!(r.cycles >= 1e7, "second app cannot finish before it arrives");
    assert!(
        r.app_cycles[1] < r.cycles,
        "response time must subtract the arrival offset"
    );
}

/// A staggered oversubscribed mix must still execute every block, and a
/// late-arriving kernel must wake idle SMs (the arrival-event path).
#[test]
fn late_arrival_wakes_idle_sms() {
    let cfg = cfg_for(MemBackendKind::FixedLatency);
    let apps = build_apps(&["NN", "DC"], &cfg);
    // App 1 arrives long after app 0 has fully drained: without arrival
    // wakeups its blocks would never be scheduled and the run would
    // report half the accesses.
    let mix = launches_at(&apps, |i| i as f64 * 5e7);
    let r = run_multi(
        &cfg,
        &mix,
        MixPlacement::CgpLocal,
        Policy::Baseline,
        FairnessPolicy::Fcfs,
    )
    .unwrap();
    let total: u64 = apps.iter().map(|a| a.total_accesses()).sum();
    assert_eq!(r.accesses.ndp_total(), total, "late kernel must still run");
}

/// Oversubscription: more kernels than stacks, all three fairness
/// policies. Every policy must run every block, deterministically.
#[test]
fn oversubscribed_mix_under_every_fairness_policy() {
    let cfg = cfg_for(MemBackendKind::FixedLatency);
    let apps = build_apps(&["NN", "KM", "DC", "HS", "NN", "KM"], &cfg);
    let total: u64 = apps.iter().map(|a| a.total_accesses()).sum();
    for fairness in [
        FairnessPolicy::Fcfs,
        FairnessPolicy::RoundRobin,
        FairnessPolicy::LeastIssued,
    ] {
        let mix = launches_at(&apps, |_| 0.0);
        let r1 = run_multi(&cfg, &mix, MixPlacement::CgpLocal, Policy::Affinity, fairness)
            .unwrap();
        let mix2 = launches_at(&apps, |_| 0.0);
        let r2 = run_multi(&cfg, &mix2, MixPlacement::CgpLocal, Policy::Affinity, fairness)
            .unwrap();
        assert_eq!(r1.accesses.ndp_total(), total, "{fairness}: lost blocks");
        assert_eq!(r1.cycles, r2.cycles, "{fairness}: nondeterministic");
        assert_eq!(r1.app_cycles, r2.app_cycles, "{fairness}: nondeterministic");
        assert_eq!(r1.app_slowdown.len(), 6);
        assert!(r1.weighted_speedup > 0.0 && r1.weighted_speedup <= 6.0 + 1e-9);
        // Apps doubled up on stacks 0/1 contend; apps 2/3 run alone on
        // their stacks and must be untouched under affinity scheduling.
        assert_eq!(r1.app_slowdown[2], 1.0, "{fairness}");
        assert_eq!(r1.app_slowdown[3], 1.0, "{fairness}");
        assert!(
            r1.app_slowdown.iter().any(|&s| s > 1.0 + 1e-9),
            "{fairness}: time-sharing must cost the doubled-up apps"
        );
    }
}

/// The coordinator façade exposes the same machinery.
#[test]
fn coordinator_run_multi_facade() {
    let cfg = cfg_for(MemBackendKind::FixedLatency);
    let apps = build_apps(&["NN", "DC"], &cfg);
    let coord = Coordinator::new(cfg.clone());
    let launches: Vec<(&BuiltWorkload, f64)> = apps.iter().map(|a| (&**a, 0.0)).collect();
    let r = coord
        .run_multi(&launches, MixPlacement::CgpLocal, Policy::Affinity)
        .unwrap();
    assert_eq!(r.app_slowdown, vec![1.0, 1.0]);
    let (times, rep) = coord
        .run_mix(
            &apps.iter().map(|a| &**a).collect::<Vec<_>>(),
            MixPlacement::CgpLocal,
        )
        .unwrap();
    assert_eq!(times.len(), 2);
    assert_eq!(rep.accesses.remote, 0);
}

// ---------------------------------------------------------------------------
// Multiprogrammed placement expectations (satellite).
// ---------------------------------------------------------------------------

/// CGP-local placement of disjoint per-app footprints serves every
/// access from the home stack — zero remote traffic — under both
/// backends, and the per-stack byte counts are backend-invariant.
#[test]
fn cgp_local_yields_zero_remote_under_both_backends() {
    let mut byte_splits = Vec::new();
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        let cfg = cfg_for(backend);
        let apps = build_apps(&["NN", "KM", "DC", "HS"], &cfg);
        let refs: Vec<&BuiltWorkload> = apps.iter().map(|a| &**a).collect();
        let mix = Mix { apps: refs };
        let (_, r) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        let total: u64 = apps.iter().map(|a| a.total_accesses()).sum();
        assert_eq!(r.accesses.remote, 0, "{backend:?}");
        assert_eq!(r.accesses.local, total, "{backend:?}");
        assert_eq!(r.remote_bytes, 0, "{backend:?}");
        byte_splits.push(r.stack_bytes.clone());
    }
    assert_eq!(
        byte_splits[0], byte_splits[1],
        "per-stack traffic split must not depend on the DRAM backend"
    );
}

/// FGP-Only placement stripes every app's pages over all stacks, so with
/// N stacks roughly (N-1)/N of each app's accesses are remote.
#[test]
fn fgp_only_yields_interleaved_expectation_under_both_backends() {
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        let cfg = cfg_for(backend);
        let apps = build_apps(&["NN", "KM", "DC", "HS"], &cfg);
        let refs: Vec<&BuiltWorkload> = apps.iter().map(|a| &**a).collect();
        let mix = Mix { apps: refs };
        let (_, r) = run_mix(&cfg, &mix, MixPlacement::FgpOnly).unwrap();
        let total: u64 = apps.iter().map(|a| a.total_accesses()).sum();
        assert_eq!(r.accesses.ndp_total(), total, "{backend:?}");
        let expect = (cfg.num_stacks - 1) as f64 / cfg.num_stacks as f64;
        let rf = r.accesses.remote_fraction();
        assert!(
            (rf - expect).abs() < 0.08,
            "{backend:?}: remote fraction {rf} vs interleaved expectation {expect}"
        );
    }
}
