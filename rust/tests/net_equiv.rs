//! Fabric-equivalence suite: the route-aware interconnect under its
//! degenerate fully-connected topology must be **bit-exact** to the
//! original point-to-point `Interconnect`, frozen verbatim in the
//! `oracle` module below (the same convention as `tests/differential/`
//! and `tests/spec_equiv/`: the oracle never changes, the code under
//! test must keep matching it).
//!
//! Three layers of evidence:
//!
//! 1. Op-level: deterministic pseudo-random hop sequences through both
//!    models return identical `f64` times (compared by `to_bits`) and
//!    identical counters, across several configs.
//! 2. Run-level: the frozen pre-fabric single-kernel event loop (the
//!    `legacy_kernel_run` body from `tests/differential/legacy.rs`,
//!    retargeted at the oracle net) matches `sim::KernelRun::run` on the
//!    live fabric field-for-field and **byte-for-byte as JSON**, for
//!    every mechanism × workload × DRAM backend.
//! 3. Hotspot regression: all-to-one traffic on a line topology
//!    concentrates on the last link — its byte count and peak-window
//!    throughput far exceed the per-link average, which is the signal
//!    the multi-hop fabric exists to expose.

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::{Coordinator, Mechanism};
use coda::net::TopologyKind;
use coda::report::Json;
use coda::sim::{map_objects, KernelRun};
use coda::stats::RunReport;
use coda::workloads::suite;

/// The pre-fabric interconnect, frozen verbatim (minus unused helpers).
/// Do not "improve" this — its value is that it never changes.
mod oracle {
    use coda::config::SystemConfig;

    #[derive(Clone, Debug)]
    pub struct Link {
        bytes_per_cycle: f64,
        latency_cycles: f64,
        next_free: f64,
        bytes_sent: u64,
        transfers: u64,
        queued_cycles: f64,
        stalled: u64,
    }

    impl Link {
        pub fn new(bytes_per_cycle: f64, latency_cycles: f64) -> Self {
            assert!(bytes_per_cycle > 0.0);
            Self {
                bytes_per_cycle,
                latency_cycles,
                next_free: 0.0,
                bytes_sent: 0,
                transfers: 0,
                queued_cycles: 0.0,
                stalled: 0,
            }
        }

        #[inline(always)]
        pub fn transfer(&mut self, now: f64, bytes: u64) -> f64 {
            let start = now.max(self.next_free);
            if start > now {
                self.stalled += 1;
            }
            self.queued_cycles += start - now;
            let occupancy = bytes as f64 / self.bytes_per_cycle;
            self.next_free = start + occupancy;
            self.bytes_sent += bytes;
            self.transfers += 1;
            start + occupancy + self.latency_cycles
        }

        pub fn bytes_sent(&self) -> u64 {
            self.bytes_sent
        }

        pub fn stalls(&self) -> u64 {
            self.stalled
        }
    }

    #[derive(Clone, Debug)]
    pub struct Interconnect {
        pub local: Vec<Link>,
        pub host: Vec<Link>,
        pub remote_out: Vec<Link>,
        pub remote_in: Vec<Link>,
    }

    impl Interconnect {
        pub fn new(cfg: &SystemConfig) -> Self {
            let n = cfg.num_stacks;
            let cyc = cfg.cycles_per_ns();
            let local_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs);
            let host_bw = cfg.gbs_to_bytes_per_cycle(cfg.host_bw_gbs) / n as f64;
            let remote_bw = cfg.gbs_to_bytes_per_cycle(cfg.remote_bw_gbs) / n as f64;
            Self {
                local: (0..n)
                    .map(|_| Link::new(local_bw, cfg.local_latency_ns * cyc))
                    .collect(),
                host: (0..n)
                    .map(|_| Link::new(host_bw, cfg.host_latency_ns * cyc))
                    .collect(),
                remote_out: (0..n)
                    .map(|_| Link::new(remote_bw, cfg.remote_latency_ns * cyc))
                    .collect(),
                remote_in: (0..n).map(|_| Link::new(remote_bw, 0.0)).collect(),
            }
        }

        #[inline]
        pub fn local_hop(&mut self, now: f64, stack: usize, bytes: u64) -> f64 {
            self.local[stack].transfer(now, bytes)
        }

        #[inline]
        pub fn remote_hop(&mut self, now: f64, src: usize, dst: usize, bytes: u64) -> f64 {
            debug_assert_ne!(src, dst);
            let t = self.remote_out[src].transfer(now, bytes);
            self.remote_in[dst].transfer(t, bytes)
        }

        #[inline]
        pub fn host_hop(&mut self, now: f64, stack: usize, bytes: u64) -> f64 {
            self.host[stack].transfer(now, bytes)
        }

        pub fn remote_bytes(&self) -> u64 {
            self.remote_out.iter().map(|l| l.bytes_sent()).sum()
        }

        pub fn host_bytes(&self) -> u64 {
            self.host.iter().map(|l| l.bytes_sent()).sum()
        }

        pub fn host_port_stalls(&self) -> u64 {
            self.host.iter().map(|l| l.stalls()).sum()
        }
    }
}

/// The pre-fabric single-kernel event loop, frozen against the oracle
/// net (the `legacy_kernel_run` body from `tests/differential/legacy.rs`
/// with `coda::net::Interconnect` swapped for `oracle::Interconnect` —
/// the only change, so any run-level divergence is the fabric's fault).
mod frozen_run {
    use super::oracle::Interconnect;
    use coda::addr::{AddressMapper, Granularity, VirtualAddress};
    use coda::config::SystemConfig;
    use coda::gpu::Topology;
    use coda::mem::{self, MemBackend, MemStats};
    use coda::sched::{Policy, Scheduler};
    use coda::stats::{AccessStats, RunReport};
    use coda::trace::KernelTrace;
    use coda::vm::{Tlb, VirtualMemory};
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
    struct TimeKey(u64, u64);

    fn key(t: f64, seq: u64) -> TimeKey {
        debug_assert!(t >= 0.0);
        TimeKey(t.to_bits(), seq)
    }

    #[derive(Clone, Copy, Debug)]
    struct SlotState {
        block_idx: u32,
        next_access: u32,
    }

    #[inline]
    fn line_hash(x: u64) -> u64 {
        let mut z = x.wrapping_mul(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z ^ (z >> 31)
    }

    pub fn legacy_kernel_run(
        cfg: &SystemConfig,
        trace: &KernelTrace,
        vm: &mut VirtualMemory,
        obj_base: &[u64],
        policy: Policy,
        migrate_on_first_touch: bool,
    ) -> RunReport {
        let topo = Topology::new(cfg);
        let mapper = AddressMapper::new(cfg);
        let mut net = Interconnect::new(cfg);
        let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
        let mut tlbs: Vec<Tlb> = (0..topo.sms.len())
            .map(|_| Tlb::new(cfg.tlb_entries))
            .collect();
        let mut sched = Scheduler::new(policy, trace.num_blocks(), cfg);

        let mut id_to_idx = vec![u32::MAX; trace.num_blocks() as usize];
        for (i, b) in trace.blocks.iter().enumerate() {
            id_to_idx[b.block_id as usize] = i as u32;
        }

        let cyc = cfg.cycles_per_ns();
        let l2_threshold = (cfg.l2_hit_rate * u32::MAX as f64) as u64;
        let l2_hit_cycles = cfg.l2_hit_ns * cyc;
        let tlb_miss_cycles = cfg.tlb_miss_ns * cyc;
        let line = cfg.line_size;
        let page_shift = cfg.page_size.trailing_zeros();
        let mlp = cfg.mlp_per_block as u32;
        let compute = cfg.compute_cycles_per_access as f64;

        let mut stats = AccessStats::default();
        let mut migrated: u64 = 0;
        let mut migrated_pages: Vec<bool> = vec![false; vm.mapped_pages() as usize];
        let mut latency_sum = 0.0f64;
        let mut latency_n: u64 = 0;
        let mut end_time = 0.0f64;
        let mut seq: u64 = 0;

        let mut heap: BinaryHeap<Reverse<(TimeKey, u32, u32)>> = BinaryHeap::new();
        let slots_per_sm = cfg.blocks_per_sm;
        let mut slots: Vec<Option<SlotState>> = vec![None; topo.sms.len() * slots_per_sm];
        let mut sm_free: Vec<f64> = vec![0.0; topo.sms.len()];

        for slot in 0..slots_per_sm {
            for sm in &topo.sms {
                if let Some(bid) = sched.next_for(sm.stack) {
                    let idx = id_to_idx[bid as usize];
                    slots[sm.id * slots_per_sm + slot] = Some(SlotState {
                        block_idx: idx,
                        next_access: 0,
                    });
                    heap.push(Reverse((key(0.0, seq), sm.id as u32, slot as u32)));
                    seq += 1;
                }
            }
        }

        while let Some(Reverse((tk, sm_id, slot_id))) = heap.pop() {
            let now = f64::from_bits(tk.0);
            let sm = topo.sms[sm_id as usize];
            let slot_key = sm_id as usize * slots_per_sm + slot_id as usize;
            let Some(state) = slots[slot_key] else { continue };
            let block = &trace.blocks[state.block_idx as usize];
            let begin = state.next_access as usize;
            let end = (begin + mlp as usize).min(block.accesses.len());

            let mut window_done = now;
            for a in &block.accesses[begin..end] {
                let vaddr = obj_base[a.obj as usize] + a.offset;
                let vline = vaddr / line;
                if line_hash(vline) & 0xFFFF_FFFF < l2_threshold {
                    stats.l2_hits += 1;
                    window_done = window_done.max(now + l2_hit_cycles);
                    continue;
                }
                let vpn = vaddr >> page_shift;
                let mut t = now;
                let pte = match tlbs[sm.id].lookup(vpn) {
                    Some(pte) => pte,
                    None => {
                        t += tlb_miss_cycles;
                        let pte = vm
                            .pte_of(VirtualAddress(vaddr))
                            .expect("workload access beyond mapped object");
                        tlbs[sm.id].fill(vpn, pte);
                        pte
                    }
                };
                let mut paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                let mut gran = pte.granularity;
                if migrate_on_first_touch
                    && gran == Granularity::Fgp
                    && !migrated_pages[vpn as usize]
                {
                    migrated_pages[vpn as usize] = true;
                    if vm.migrate_to_cgp(VirtualAddress(vaddr), sm.stack).is_ok() {
                        migrated += 1;
                        let copy_bytes = cfg.page_size * (cfg.num_stacks as u64 - 1)
                            / cfg.num_stacks as u64;
                        t = net.remote_hop(
                            t,
                            (sm.stack + 1) % cfg.num_stacks,
                            sm.stack,
                            copy_bytes,
                        );
                        let pte = vm.pte_of(VirtualAddress(vaddr)).unwrap();
                        tlbs[sm.id].fill(vpn, pte);
                        paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                        gran = pte.granularity;
                    }
                }
                let dst = mapper.stack_of(paddr, gran);
                let done = if dst == sm.stack {
                    stats.local += 1;
                    let t1 = net.local_hop(t, dst, line);
                    stacks[dst].access(t1, paddr, line).done
                } else {
                    stats.remote += 1;
                    let t1 = net.remote_hop(t, sm.stack, dst, line);
                    let t2 = stacks[dst].access(t1, paddr, line).done;
                    net.remote_hop(t2, dst, sm.stack, line)
                };
                latency_sum += done - now;
                latency_n += 1;
                window_done = window_done.max(done);
            }
            let issued = (end - begin) as f64;
            let c_start = window_done.max(sm_free[sm.id]);
            let t_next = c_start + compute * issued;
            sm_free[sm.id] = t_next;
            end_time = end_time.max(t_next);

            if end < block.accesses.len() {
                slots[slot_key] = Some(SlotState {
                    block_idx: state.block_idx,
                    next_access: end as u32,
                });
                heap.push(Reverse((key(t_next, seq), sm_id, slot_id)));
                seq += 1;
            } else {
                match sched.next_for(sm.stack) {
                    Some(bid) => {
                        slots[slot_key] = Some(SlotState {
                            block_idx: id_to_idx[bid as usize],
                            next_access: 0,
                        });
                        heap.push(Reverse((key(t_next, seq), sm_id, slot_id)));
                        seq += 1;
                    }
                    None => slots[slot_key] = None,
                }
            }
        }

        let tlb_hits: u64 = tlbs.iter().map(|t| t.hits).sum();
        let tlb_total: u64 = tlbs.iter().map(|t| t.hits + t.misses).sum();
        let row_hit_rate = {
            let rates: Vec<f64> = stacks.iter().map(|s| s.row_hit_rate()).collect();
            coda::stats::mean(&rates)
        };
        let mut mem_stats = MemStats::default();
        for s in &stacks {
            mem_stats.add(&s.stats());
        }
        RunReport {
            workload: trace.name.clone(),
            mechanism: String::new(),
            cycles: end_time,
            accesses: stats,
            stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
            remote_bytes: net.remote_bytes(),
            mean_mem_latency: if latency_n == 0 {
                0.0
            } else {
                latency_sum / latency_n as f64
            },
            tlb_hit_rate: if tlb_total == 0 {
                0.0
            } else {
                tlb_hits as f64 / tlb_total as f64
            },
            row_hit_rate,
            mem_backend: cfg.mem_backend.to_string(),
            bank_conflicts: mem_stats.row_conflicts,
            refresh_stalls: mem_stats.refresh_stalls,
            cgp_pages: 0,
            fgp_pages: 0,
            migrated_pages: migrated,
            ..Default::default()
        }
    }
}

const MECHS: [Mechanism; 7] = [
    Mechanism::FgpOnly,
    Mechanism::CgpOnly,
    Mechanism::CgpFta,
    Mechanism::MigrationFta,
    Mechanism::Coda,
    Mechanism::FgpAffinity,
    Mechanism::CodaStealing,
];

const WORKLOADS: [&str; 5] = ["PR", "DC", "KM", "NN", "HS3D"];

/// Small deterministic LCG so both nets see the same op sequence.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }
}

/// Op-level differential: identical hop sequences must return identical
/// times (bit-exact) and identical counters under the degenerate fabric.
#[test]
fn fully_connected_hops_are_bit_exact_to_oracle() {
    let mut configs = vec![SystemConfig::default(), SystemConfig::test_small()];
    for n in [2, 3, 8] {
        let mut c = SystemConfig::default();
        c.num_stacks = n;
        configs.push(c);
    }
    let mut odd = SystemConfig::default();
    odd.remote_bw_gbs = 7.0;
    odd.remote_latency_ns = 123.0;
    configs.push(odd);
    // The multi-hop knobs must not perturb the degenerate fabric.
    let mut knobs = SystemConfig::default();
    knobs.link_bw_gbs = 99.0;
    knobs.hop_latency_ns = 1.0;
    knobs.net_window_cycles = 16.0;
    configs.push(knobs);

    for (ci, cfg) in configs.iter().enumerate() {
        assert_eq!(cfg.topology, TopologyKind::FullyConnected);
        let n = cfg.num_stacks;
        let mut new = coda::net::Interconnect::new(cfg);
        let mut old = oracle::Interconnect::new(cfg);
        let mut rng = Lcg(0x5EED_0000 + ci as u64);
        for op in 0..5000 {
            let r = rng.next();
            let src = (r >> 8) as usize % n;
            let mut dst = (r >> 24) as usize % n;
            let bytes = 1 + (r & 0xFFF);
            // Interleave clustered and spread-out timestamps so links go
            // busy and idle again.
            let now = ((r >> 40) & 0x3FF) as f64 * if op % 7 == 0 { 100.0 } else { 0.25 };
            let (now_new, now_old) = match r % 3 {
                0 => (new.local_hop(now, src, bytes), old.local_hop(now, src, bytes)),
                1 => {
                    if dst == src {
                        dst = (dst + 1) % n;
                    }
                    (
                        new.remote_hop(now, src, dst, bytes),
                        old.remote_hop(now, src, dst, bytes),
                    )
                }
                _ => (new.host_hop(now, src, bytes), old.host_hop(now, src, bytes)),
            };
            assert_eq!(
                now_new.to_bits(),
                now_old.to_bits(),
                "config {ci}, op {op}: fabric time {now_new} != oracle {now_old}"
            );
        }
        assert_eq!(new.remote_bytes(), old.remote_bytes(), "config {ci}: remote bytes");
        assert_eq!(new.host_bytes(), old.host_bytes(), "config {ci}: host bytes");
        assert_eq!(
            new.host_port_stalls(),
            old.host_port_stalls(),
            "config {ci}: host stalls"
        );
        assert!(new.link_stats().is_empty(), "config {ci}: degenerate link stats");
    }
}

/// Every RunReport field the frozen loop produced, compared bit-exactly.
fn assert_reports_identical(new: &RunReport, old: &RunReport, what: &str) {
    assert_eq!(new.workload, old.workload, "{what}: workload");
    assert_eq!(new.mechanism, old.mechanism, "{what}: mechanism");
    assert_eq!(new.cycles.to_bits(), old.cycles.to_bits(), "{what}: cycles");
    assert_eq!(new.accesses, old.accesses, "{what}: access counts");
    assert_eq!(new.stack_bytes, old.stack_bytes, "{what}: stack bytes");
    assert_eq!(new.remote_bytes, old.remote_bytes, "{what}: remote bytes");
    assert_eq!(
        new.mean_mem_latency.to_bits(),
        old.mean_mem_latency.to_bits(),
        "{what}: latency"
    );
    assert_eq!(
        new.tlb_hit_rate.to_bits(),
        old.tlb_hit_rate.to_bits(),
        "{what}: tlb"
    );
    assert_eq!(
        new.row_hit_rate.to_bits(),
        old.row_hit_rate.to_bits(),
        "{what}: row hit rate"
    );
    assert_eq!(new.mem_backend, old.mem_backend, "{what}: backend");
    assert_eq!(new.bank_conflicts, old.bank_conflicts, "{what}: conflicts");
    assert_eq!(new.refresh_stalls, old.refresh_stalls, "{what}: refresh");
    assert_eq!(new.cgp_pages, old.cgp_pages, "{what}: cgp pages");
    assert_eq!(new.fgp_pages, old.fgp_pages, "{what}: fgp pages");
    assert_eq!(new.migrated_pages, old.migrated_pages, "{what}: migrated");
    assert_eq!(new.topology, old.topology, "{what}: topology tag");
    assert_eq!(
        new.net_window_cycles.to_bits(),
        old.net_window_cycles.to_bits(),
        "{what}: window"
    );
    assert_eq!(new.link_stats, old.link_stats, "{what}: link stats");
}

/// Run-level differential: the live fabric under the default topology
/// must reproduce the frozen pre-fabric loop field-for-field and render
/// byte-identical JSON, for every mechanism × workload × backend.
#[test]
fn degenerate_fabric_runs_are_bit_exact_to_frozen_loop() {
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        let mut cfg = SystemConfig::test_small();
        cfg.mem_backend = backend;
        let coord = Coordinator::new(cfg.clone());
        for name in WORKLOADS {
            let wl = suite::build(name, &cfg).unwrap();
            for mech in MECHS {
                let plan = coord.plan_for(&wl, mech);
                let policy = mech.policy();
                let (mut vm_new, bases_new, _, _) =
                    map_objects(&cfg, &wl.trace, &plan).unwrap();
                let new = KernelRun {
                    cfg: &cfg,
                    trace: &wl.trace,
                    vm: &mut vm_new,
                    obj_base: &bases_new,
                    policy,
                    migrate_on_first_touch: plan.migrate_on_first_touch,
                }
                .run();
                let (mut vm_old, bases_old, _, _) =
                    map_objects(&cfg, &wl.trace, &plan).unwrap();
                // The frozen loop predates the VA newtype; hand it raw u64s.
                let bases_old: Vec<u64> = bases_old.iter().map(|b| b.0).collect();
                let old = frozen_run::legacy_kernel_run(
                    &cfg,
                    &wl.trace,
                    &mut vm_old,
                    &bases_old,
                    policy,
                    plan.migrate_on_first_touch,
                );
                let what = format!("{name}/{}/{}", mech.name(), cfg.mem_backend);
                assert_reports_identical(&new, &old, &what);
                assert!(new.topology.is_empty(), "{what}: degenerate topology tag");
                assert!(new.link_stats.is_empty(), "{what}: degenerate link stats");
                assert_eq!(
                    Json::from(&new).render(),
                    Json::from(&old).render(),
                    "{what}: JSON must be byte-identical"
                );
            }
        }
    }
}

/// Hotspot regression: all-to-one on a line concentrates traffic on the
/// link into the sink, so its per-window peak dwarfs the per-link
/// average — the signal averages hide and the fabric counters exist to
/// expose.
#[test]
fn line_all_to_one_hotspot_peak_exceeds_average() {
    let mut cfg = SystemConfig::default();
    cfg.topology = TopologyKind::Line;
    cfg.net_window_cycles = 8192.0;
    let n = cfg.num_stacks;
    let mut net = coda::net::Interconnect::new(&cfg);
    let mut t = 0.0;
    for round in 0..64 {
        for src in 1..n {
            t = net.remote_hop(round as f64 * 4.0, src, 0, 256);
        }
    }
    assert!(t > 0.0);
    let stats = net.link_stats();
    assert_eq!(stats.len(), 2 * (n - 1));
    let total: u64 = stats.iter().map(|l| l.bytes).sum();
    let avg = total as f64 / stats.len() as f64;
    let hot = stats.iter().find(|l| l.from == 1 && l.to == 0).unwrap();
    // Every message crosses 1 -> 0: (n-1) sources x 64 rounds x 256 B.
    assert_eq!(hot.bytes, (n as u64 - 1) * 64 * 256);
    assert!(
        hot.bytes as f64 > 2.5 * avg,
        "hotspot {} vs per-link average {avg}",
        hot.bytes
    );
    assert!(hot.stalls > 0, "the hot link must have queued transfers");
    // Peak-per-window throughput also dwarfs the hot link's own lifetime
    // average: the burst happens early, then the fabric drains.
    assert!(hot.peak_window_bytes > 0);
    let makespan_windows = (t / cfg.net_window_cycles).ceil().max(1.0);
    let lifetime_avg = hot.bytes as f64 / makespan_windows;
    assert!(
        hot.peak_window_bytes as f64 >= lifetime_avg,
        "peak window {} vs lifetime average {lifetime_avg}",
        hot.peak_window_bytes
    );
    // The reverse direction carried nothing.
    let cold = stats.iter().find(|l| l.from == 0 && l.to == 1).unwrap();
    assert_eq!(cold.bytes, 0);
}
