//! Parallel orchestration is a wall-clock optimization only.
//!
//! `session.rs` fans run-alone baselines (solo and host-split) and
//! `[sweep]` expansion out over `par::parallel_map` worker threads. This
//! suite proves the parallel paths **bit-exact** (every `Report` f64
//! field compared by `to_bits`, every counter by equality) and
//! **byte-identical** (rendered JSON) to the sequential path
//! (`sim_threads = 1`), across thread counts — including `0` = auto —
//! and both DRAM backends. If a fan-out ever let scheduling order leak
//! into a simulated number, these tests are the tripwire.

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::Mechanism;
use coda::multiprog::MixPlacement;
use coda::sched::{FairnessPolicy, Policy};
use coda::session::{run_spec, Report, Session};
use coda::spec::{ExperimentSpec, SweepSpec, WorkloadSel};

const BACKENDS: [MemBackendKind; 2] = [MemBackendKind::FixedLatency, MemBackendKind::BankLevel];
/// Thread counts compared against the sequential baseline (0 = one per
/// available core, whatever this machine has).
const THREADS: [usize; 3] = [2, 4, 0];

fn cfg(backend: MemBackendKind, threads: usize) -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = backend;
    c.sim_threads = threads;
    c
}

/// Every f64 field bit-exact, every counter equal, JSON byte-identical.
fn assert_report_identical(a: &Report, b: &Report, ctx: &str) {
    assert_eq!(a.run.cycles.to_bits(), b.run.cycles.to_bits(), "{ctx}: cycles");
    assert_eq!(
        a.run.mean_mem_latency.to_bits(),
        b.run.mean_mem_latency.to_bits(),
        "{ctx}: mean_mem_latency"
    );
    assert_eq!(
        a.run.tlb_hit_rate.to_bits(),
        b.run.tlb_hit_rate.to_bits(),
        "{ctx}: tlb_hit_rate"
    );
    assert_eq!(
        a.run.row_hit_rate.to_bits(),
        b.run.row_hit_rate.to_bits(),
        "{ctx}: row_hit_rate"
    );
    assert_eq!(
        a.run.weighted_speedup.to_bits(),
        b.run.weighted_speedup.to_bits(),
        "{ctx}: weighted_speedup"
    );
    assert_eq!(
        a.run.host_cycles.to_bits(),
        b.run.host_cycles.to_bits(),
        "{ctx}: host_cycles"
    );
    assert_eq!(
        a.run.host_slowdown.to_bits(),
        b.run.host_slowdown.to_bits(),
        "{ctx}: host_slowdown"
    );
    assert_eq!(
        a.run.ndp_slowdown.to_bits(),
        b.run.ndp_slowdown.to_bits(),
        "{ctx}: ndp_slowdown"
    );
    assert_eq!(
        a.run.host_bw_share.to_bits(),
        b.run.host_bw_share.to_bits(),
        "{ctx}: host_bw_share"
    );
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(&a.run.app_cycles),
        bits(&b.run.app_cycles),
        "{ctx}: app_cycles"
    );
    assert_eq!(
        bits(&a.run.app_slowdown),
        bits(&b.run.app_slowdown),
        "{ctx}: app_slowdown"
    );
    assert_eq!(a.run.accesses, b.run.accesses, "{ctx}: access counts");
    assert_eq!(a.run.stack_bytes, b.run.stack_bytes, "{ctx}: stack_bytes");
    assert_eq!(a.run.remote_bytes, b.run.remote_bytes, "{ctx}: remote_bytes");
    assert_eq!(a.run.host_bytes, b.run.host_bytes, "{ctx}: host_bytes");
    assert_eq!(
        a.run.host_port_stalls, b.run.host_port_stalls,
        "{ctx}: host_port_stalls"
    );
    assert_eq!(a.run.workload, b.run.workload, "{ctx}: workload label");
    assert_eq!(a.run.mechanism, b.run.mechanism, "{ctx}: mechanism label");
    assert_eq!(a.spec_name, b.spec_name, "{ctx}: spec label");
    assert_eq!(a.sources.len(), b.sources.len(), "{ctx}: source rows");
    for (sa, sb) in a.sources.iter().zip(&b.sources) {
        assert_eq!(sa.cycles.to_bits(), sb.cycles.to_bits(), "{ctx}: source cycles");
        assert_eq!(
            sa.slowdown.map(f64::to_bits),
            sb.slowdown.map(f64::to_bits),
            "{ctx}: source slowdown"
        );
    }
    // The byte-level catch-all: anything the field list above misses.
    assert_eq!(
        a.to_json().render(),
        b.to_json().render(),
        "{ctx}: JSON rendering"
    );
}

/// A staggered three-app mix with solo baselines: the fan-out covers one
/// run-alone simulation per app, collected in app order.
fn mix_spec() -> ExperimentSpec<'static> {
    ExperimentSpec::shared(
        vec![
            (WorkloadSel::Named("NN"), 0.0),
            (WorkloadSel::Named("KM"), 2_000.0),
            (WorkloadSel::Named("DC"), 4_000.0),
        ],
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    )
}

#[test]
fn solo_baselines_parallel_is_bit_exact() {
    for backend in BACKENDS {
        let seq = Session::new(cfg(backend, 1), mix_spec())
            .unwrap()
            .run()
            .unwrap();
        assert!(!seq.run.app_slowdown.is_empty(), "solo baselines must run");
        for threads in THREADS {
            let par = Session::new(cfg(backend, threads), mix_spec())
                .unwrap()
                .run()
                .unwrap();
            assert_report_identical(&seq, &par, &format!("solo {backend:?} t={threads}"));
        }
    }
}

/// NDP kernels + host co-run with host-split baselines: the fan-out
/// covers the NDP-alone and host-alone runs, each over a re-mapped
/// (identical) layout.
fn hostmix_spec() -> ExperimentSpec<'static> {
    ExperimentSpec::hostmix(
        vec![
            (WorkloadSel::Named("NN"), 0.0),
            (WorkloadSel::Named("KM"), 0.0),
        ],
        Some(WorkloadSel::Named("DC")),
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    )
}

#[test]
fn host_split_baselines_parallel_is_bit_exact() {
    for backend in BACKENDS {
        let seq = Session::new(cfg(backend, 1), hostmix_spec())
            .unwrap()
            .run()
            .unwrap();
        assert!(seq.run.host_cycles > 0.0, "the host stream must run");
        assert!(
            seq.run.ndp_slowdown > 0.0,
            "host-split baselines must produce slowdowns"
        );
        for threads in THREADS {
            let par = Session::new(cfg(backend, threads), hostmix_spec())
                .unwrap()
                .run()
                .unwrap();
            assert_report_identical(&seq, &par, &format!("host-split {backend:?} t={threads}"));
        }
    }
}

/// A kernel-dispatch sweep: the fan-out covers one full session per
/// sweep value, collected in value order with the point labels intact.
fn sweep_spec() -> ExperimentSpec<'static> {
    let mut spec = ExperimentSpec::kernel(WorkloadSel::Named("PR"), Mechanism::FgpOnly);
    spec.name = Some("par-sweep".into());
    spec.sweep = Some(SweepSpec {
        key: "remote_bw_gbs".into(),
        values: vec!["8".into(), "32".into(), "128".into()],
    });
    spec
}

#[test]
fn sweep_parallel_is_bit_exact() {
    for backend in BACKENDS {
        let seq = run_spec(&cfg(backend, 1), &sweep_spec()).unwrap();
        assert_eq!(seq.len(), 3);
        assert_eq!(
            seq[0].spec_name.as_deref(),
            Some("par-sweep[remote_bw_gbs=8]")
        );
        for threads in THREADS {
            let par = run_spec(&cfg(backend, threads), &sweep_spec()).unwrap();
            assert_eq!(par.len(), seq.len(), "sweep point count");
            for (i, (s, p)) in seq.iter().zip(&par).enumerate() {
                assert_report_identical(
                    s,
                    p,
                    &format!("sweep[{i}] {backend:?} t={threads}"),
                );
            }
        }
    }
}

/// A sweeping spec whose points themselves run parallel solo baselines:
/// nested fan-out (points × baselines) must still be bit-exact.
#[test]
fn nested_sweep_over_baselines_is_bit_exact() {
    let mut spec = mix_spec();
    spec.sweep = Some(SweepSpec {
        key: "remote_bw_gbs".into(),
        values: vec!["8".into(), "64".into()],
    });
    let seq = run_spec(&cfg(MemBackendKind::FixedLatency, 1), &spec).unwrap();
    let par = run_spec(&cfg(MemBackendKind::FixedLatency, 4), &spec).unwrap();
    assert_eq!(seq.len(), 2);
    for (i, (s, p)) in seq.iter().zip(&par).enumerate() {
        assert!(!s.run.app_slowdown.is_empty(), "baselines must run");
        assert_report_identical(s, p, &format!("nested[{i}]"));
    }
}

/// Repeated threaded runs agree with themselves: no run-to-run jitter
/// from the worker pool.
#[test]
fn parallel_runs_are_deterministic() {
    let a = Session::new(cfg(MemBackendKind::FixedLatency, 4), mix_spec())
        .unwrap()
        .run()
        .unwrap();
    let b = Session::new(cfg(MemBackendKind::FixedLatency, 4), mix_spec())
        .unwrap()
        .run()
        .unwrap();
    assert_report_identical(&a, &b, "repeat");
}

/// The CLI knob reaches the config: `--threads`-equivalent `--set`
/// spelling parses, and a spec's `[system]` override may set it too.
#[test]
fn sim_threads_is_settable_through_spec_overrides() {
    let mut spec = mix_spec();
    spec.overrides.push(("sim_threads".into(), "3".into()));
    let s = Session::new(cfg(MemBackendKind::FixedLatency, 1), spec).unwrap();
    assert_eq!(s.config().sim_threads, 3);
    let seq = Session::new(cfg(MemBackendKind::FixedLatency, 1), mix_spec())
        .unwrap()
        .run()
        .unwrap();
    let mut spec = mix_spec();
    spec.overrides.push(("sim_threads".into(), "3".into()));
    let over = Session::new(cfg(MemBackendKind::FixedLatency, 1), spec)
        .unwrap()
        .run()
        .unwrap();
    assert_report_identical(&seq, &over, "override-threads");
    // The spec-level override also governs the [sweep] expansion itself
    // (run_spec peeks at it before fanning out) — and, like every other
    // thread-count choice, leaves the reports bit-identical.
    let plain = run_spec(&cfg(MemBackendKind::FixedLatency, 1), &sweep_spec()).unwrap();
    let mut swept = sweep_spec();
    swept.overrides.push(("sim_threads".into(), "2".into()));
    let threaded = run_spec(&cfg(MemBackendKind::FixedLatency, 1), &swept).unwrap();
    assert_eq!(plain.len(), threaded.len());
    for (i, (s, p)) in plain.iter().zip(&threaded).enumerate() {
        assert_report_identical(s, p, &format!("sweep-override[{i}]"));
    }
}
