//! Property tests for `Scheduler::next_for`: across random (stacks,
//! blocks, policy, pull-interleaving) cases, every block is issued
//! exactly once — work stealing may reorder and rebalance, but it must
//! never duplicate or drop a block.

// Case generators mutate a default config; the lint's suggested struct
// literal obscures which knobs each property varies.
#![allow(clippy::field_reassign_with_default)]

use coda::config::SystemConfig;
use coda::proptest_lite::{run_prop, usize_in, PropConfig};
use coda::rng::Rng;
use coda::sched::{Policy, Scheduler};

#[derive(Debug)]
struct Case {
    cfg: SystemConfig,
    num_blocks: u32,
    policy: Policy,
    /// Random interleaving of per-stack pulls to exercise asymmetric
    /// drain orders (the shapes that make stealing pick odd victims).
    pulls: Vec<usize>,
}

fn gen_case(rng: &mut Rng) -> Case {
    let mut cfg = SystemConfig::default();
    cfg.num_stacks = 1 << rng.range(0, 4); // 1, 2, 4, 8
    cfg.sms_per_stack = usize_in(rng, 1, 5);
    cfg.blocks_per_sm = usize_in(rng, 1, 9);
    let num_blocks = rng.range(0, 400) as u32;
    let policy = *rng.choose(&[Policy::Baseline, Policy::Affinity, Policy::AffinityStealing]);
    let pulls = (0..usize_in(rng, 0, 2 * num_blocks as usize + 2))
        .map(|_| usize_in(rng, 0, cfg.num_stacks))
        .collect();
    Case {
        cfg,
        num_blocks,
        policy,
        pulls,
    }
}

fn check_case(case: &Case) -> Result<(), String> {
    let mut sched = Scheduler::new(case.policy, case.num_blocks, &case.cfg);
    let mut seen = vec![0u32; case.num_blocks as usize];
    let mut record = |bid: u32| -> Result<(), String> {
        let slot = seen
            .get_mut(bid as usize)
            .ok_or_else(|| format!("issued unknown block {bid}"))?;
        *slot += 1;
        if *slot > 1 {
            return Err(format!("block {bid} issued {} times", *slot));
        }
        Ok(())
    };
    // Phase 1: the random interleaving.
    for &stack in &case.pulls {
        if let Some(bid) = sched.next_for(stack) {
            record(bid)?;
        }
    }
    // Phase 2: deterministic round-robin sweeps until every stack runs
    // dry (under Affinity each stack drains its own queue; under
    // Baseline/Stealing any stack could drain everything).
    loop {
        let mut progressed = false;
        for stack in 0..case.cfg.num_stacks {
            while let Some(bid) = sched.next_for(stack) {
                record(bid)?;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    if !sched.empty() {
        return Err(format!(
            "{} blocks left undispatched after all stacks ran dry",
            sched.remaining()
        ));
    }
    if let Some(bid) = seen.iter().position(|&n| n != 1) {
        return Err(format!("block {bid} issued {} times", seen[bid]));
    }
    // Stealing must actually have happened somewhere across the suite's
    // asymmetric drains; checked per-case only where it is forced below.
    Ok(())
}

#[test]
fn every_block_issued_exactly_once() {
    run_prop(
        PropConfig {
            cases: 200,
            seed: 0x5CED_0001,
        },
        gen_case,
        check_case,
    );
}

/// Deterministic corner: a single stack pulling everything under each
/// policy (stealing has no victim; must not panic or loop).
#[test]
fn single_consumer_drains_all_policies() {
    let cfg = SystemConfig::default();
    for policy in [Policy::Baseline, Policy::Affinity, Policy::AffinityStealing] {
        let mut sched = Scheduler::new(policy, 96, &cfg);
        let mut n = 0;
        for stack in (0..cfg.num_stacks).cycle() {
            match sched.next_for(stack) {
                Some(_) => n += 1,
                None if sched.empty() => break,
                None => continue,
            }
        }
        assert_eq!(n, 96, "{policy:?}");
    }
}

/// Forced-steal shape: one stack pulls everything under stealing; every
/// block still issues exactly once and steals are counted.
#[test]
fn forced_stealing_preserves_exactly_once() {
    let cfg = SystemConfig::default();
    let mut sched = Scheduler::new(Policy::AffinityStealing, 192, &cfg);
    let mut seen = vec![false; 192];
    while let Some(bid) = sched.next_for(0) {
        assert!(!seen[bid as usize], "block {bid} issued twice");
        seen[bid as usize] = true;
    }
    assert!(sched.empty());
    assert!(seen.iter().all(|&x| x));
    // Stack 0 owns 48 of the 192 blocks (Eq 1); the rest are steals.
    assert_eq!(sched.steals, 192 - 48);
}
