//! Service-mode integration coverage: the open-loop `[arrivals]` stream
//! end to end, the fixed-memory quantile sketch against exact order
//! statistics, and the frozen-oracle guarantee that specs *without* an
//! `[arrivals]` section still emit byte-identical JSON.

use coda::config::{MemBackendKind, SystemConfig};
use coda::multiprog::MixPlacement;
use coda::proptest_lite::{run_prop, PropConfig};
use coda::sched::{FairnessPolicy, Policy};
use coda::session::Session;
use coda::spec::{ArrivalKind, ArrivalSpec, ExperimentSpec, WorkloadSel};
use coda::stats::QuantileSketch;
use coda::trace::{Access, BlockTrace, Category, KernelTrace, ObjectDesc};
use coda::workloads::BuiltWorkload;
use std::path::PathBuf;

/// Exact nearest-rank quantile over a sorted sample (the definition the
/// sketch's documentation promises to approximate).
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

/// The sketch's documented accuracy: p50/p99 within 1% relative error of
/// the exact sort on randomized streams (bucket width is 1/128, so a
/// midpoint answer is within ~1/256 of any member of its bucket).
#[test]
fn sketch_percentiles_track_exact_order_statistics() {
    run_prop(
        PropConfig {
            cases: 64,
            ..PropConfig::default()
        },
        |rng| {
            let n = 100 + rng.below(2000) as usize;
            // Magnitudes from ~1 to ~1e6 cycles, fractional values
            // included — the realistic response-time range.
            (0..n)
                .map(|_| 1.0 + (rng.below(1_000_000_000) as f64) / 1000.0)
                .collect::<Vec<f64>>()
        },
        |xs| {
            let mut sk = QuantileSketch::new();
            for &x in xs {
                sk.record(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(f64::total_cmp);
            for q in [0.50, 0.99] {
                let exact = exact_quantile(&sorted, q);
                let got = sk.quantile(q);
                let rel = (got - exact).abs() / exact;
                if rel > 0.01 {
                    return Err(format!(
                        "q={q}: sketch {got} vs exact {exact} ({:.3}% off)",
                        rel * 100.0
                    ));
                }
            }
            if sk.count() != xs.len() as u64 {
                return Err(format!("count {} != {}", sk.count(), xs.len()));
            }
            Ok(())
        },
    );
}

/// A minimal one-block, one-access kernel: the cheapest possible request,
/// so a million of them stay fast enough for the test suite.
fn one_block_workload() -> BuiltWorkload {
    BuiltWorkload {
        name: "unit",
        category: Category::BlockExclusive,
        trace: KernelTrace {
            name: "unit".into(),
            threads_per_block: 1,
            objects: vec![ObjectDesc {
                name: "buf".into(),
                bytes: 4096,
            }],
            blocks: vec![BlockTrace {
                block_id: 0,
                accesses: vec![Access {
                    obj: 0,
                    offset: 0,
                    write: false,
                }],
            }],
        },
        ir: None,
        env: coda::analysis::ParamEnv::new(1),
    }
}

/// The ISSUE acceptance bar: an open-loop run of >= 1M requests completes,
/// and the percentile state is the fixed-memory sketch (the source keeps a
/// recycled request slab — no per-request `Vec` survives the stream).
#[test]
fn million_request_stream_completes_with_streaming_percentiles() {
    let wl = one_block_workload();
    let mut spec = ExperimentSpec::shared(
        vec![(WorkloadSel::Prebuilt(&wl), 0.0)],
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    );
    // One request every 25 cycles: far below the 96-slot capacity, so the
    // stream drains as it arrives and every request completes.
    spec.arrivals = Some(ArrivalSpec {
        kind: ArrivalKind::Trace,
        interarrivals: vec![25.0],
        requests: Some(1_000_000),
        ..ArrivalSpec::default()
    });
    let r = Session::new(SystemConfig::test_small(), spec)
        .unwrap()
        .run()
        .unwrap();
    let svc = r.run.service.as_ref().expect("service stats");
    assert_eq!(svc.requests_offered, 1_000_000);
    assert_eq!(svc.requests_completed, 1_000_000);
    assert_eq!(svc.requests_incomplete, 0);
    // The stream spans >= 25M cycles of simulated time.
    assert!(r.run.cycles >= 25.0 * 1_000_000.0);
    assert!(svc.mean_response > 0.0);
    assert!(svc.p50_response > 0.0);
    assert!(svc.p50_response <= svc.p99_response);
    assert!(svc.p99_response <= svc.p999_response);
    assert!(svc.p999_response <= svc.max_response);
    // Sub-saturation: achieved throughput tracks the offered rate.
    assert!(svc.achieved_rate > 0.9 * svc.offered_rate);
}

/// One open-loop Poisson run on the cycle-accurate backend with the
/// given refresh interval.
fn run_cycle_service(trefi_ns: f64) -> (coda::stats::RunReport, SystemConfig) {
    let wl = one_block_workload();
    let mut spec = ExperimentSpec::shared(
        vec![(WorkloadSel::Prebuilt(&wl), 0.0)],
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    );
    spec.arrivals = Some(ArrivalSpec {
        kind: ArrivalKind::Poisson,
        rate: Some(0.02), // one request every ~50 cycles: far below saturation
        requests: Some(20_000),
        seed: Some(0xC0DA),
        ..ArrivalSpec::default()
    });
    let mut cfg = SystemConfig::test_small();
    cfg.mem_backend = MemBackendKind::CycleAccurate;
    cfg.dram_trefi_ns = trefi_ns;
    cfg.validate().unwrap();
    let r = Session::new(cfg.clone(), spec).unwrap().run().unwrap();
    (r.run, cfg)
}

/// Service mode × cycle backend: an open-loop Poisson stream completes
/// with ordered percentiles, byte accounting closes against the access
/// counts, and aggressive refresh strictly fattens the tail relative to
/// a refresh-disabled run of the same stream.
#[test]
fn cycle_backend_service_percentiles_bytes_and_refresh_tail() {
    // Refresh pushed out of reach: the tail baseline.
    let (calm, cfg) = run_cycle_service(1e9);
    let svc = calm.service.as_ref().expect("service stats");
    assert_eq!(svc.requests_offered, 20_000);
    assert_eq!(svc.requests_completed, 20_000);
    assert_eq!(svc.requests_incomplete, 0);
    assert!(svc.p50_response > 0.0);
    assert!(svc.p50_response <= svc.p99_response);
    assert!(svc.p99_response <= svc.p999_response);
    assert!(svc.p999_response <= svc.max_response);
    assert_eq!(calm.mem_backend, "cycle");
    assert_eq!(calm.refresh_stalls, 0, "tREFI = 1e9 ns must never fire");
    // Byte accounting closes: every non-L2 NDP access moves one line
    // through a stack's DRAM (posted writes count at accept, so nothing
    // leaks even when the run ends with writes queued).
    let total: u64 = calm.stack_bytes.iter().sum();
    assert_eq!(
        total,
        calm.accesses.ndp_total() * cfg.line_size,
        "byte accounting must close under the cycle backend"
    );

    // Aggressive refresh: a 500 ns window with a 260 ns blackout puts
    // over half of all time inside a blackout, so the slow tail must
    // visibly fatten while the stream still completes.
    let (hot, _) = run_cycle_service(500.0);
    let hsvc = hot.service.as_ref().expect("service stats");
    assert_eq!(hsvc.requests_completed, 20_000);
    assert!(hot.refresh_stalls > 0, "refresh windows must actually fire");
    assert!(
        hsvc.p999_response > svc.p999_response,
        "refresh must fatten the tail: hot p999 {} vs calm p999 {}",
        hsvc.p999_response,
        svc.p999_response
    );
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("session_no_arrivals.txt")
}

/// JSON of a fixed-mix session run without an `[arrivals]` section — the
/// byte-identity oracle for the service-mode PR (conditional emission
/// keeps pre-service reports unchanged).
fn render_no_arrivals_json() -> String {
    let spec = ExperimentSpec::shared(
        vec![
            (WorkloadSel::Named("NN"), 0.0),
            (WorkloadSel::Named("KM"), 0.0),
        ],
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    );
    let r = Session::new(SystemConfig::test_small(), spec)
        .unwrap()
        .run()
        .unwrap();
    let mut out = String::from("# golden: shared NN+KM session JSON (test_small), no [arrivals]\n");
    out.push_str(&r.to_json().render());
    out.push('\n');
    out
}

/// Specs without `[arrivals]` produce byte-identical JSON to the
/// pre-service output (frozen-oracle convention: the snapshot is recorded
/// on the first toolchain run and any later drift fails loudly).
#[test]
fn no_arrivals_spec_json_matches_golden_snapshot() {
    let path = golden_path();
    let got = render_no_arrivals_json();
    assert_eq!(got, render_no_arrivals_json(), "snapshot is not deterministic");
    assert!(
        !got.contains("requests_offered") && !got.contains("p99_response"),
        "a no-[arrivals] run must not emit service fields"
    );

    let update = std::env::var("CODA_UPDATE_GOLDEN").is_ok();
    match std::fs::read_to_string(&path) {
        Ok(want) if !update && !want.starts_with("# PENDING-RECORD") => {
            assert_eq!(
                got, want,
                "no-[arrivals] session JSON drifted; if the change is \
                 intentional rerun with CODA_UPDATE_GOLDEN=1 and commit {path:?}"
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &got).unwrap();
            eprintln!("recorded golden snapshot at {path:?}");
        }
    }
}
