//! Sharded-engine integration coverage: the `shard_stacks` parallel
//! engine against its bit-exactness oracle (the sequential engine).
//!
//! Three regimes, per the sharded engine's contract:
//!
//! * **Stack-private traffic** (CGP-local placement, affinity dispatch):
//!   no cross-shard messages exist, so every report field must be
//!   bit-identical to the sequential run — only `mean_mem_latency` may
//!   differ in the last ulp (per-shard partial sums add in a different
//!   order).
//! * **Remote-heavy traffic** (FGP-only placement): cross-shard accesses
//!   travel as mailbox messages, so exact event interleavings differ on
//!   time ties. Placement-determined invariants stay exact — access
//!   counts, per-stack bytes, remote bytes — and cycles agree within a
//!   small tolerance.
//! * **Degenerate configs** (one stack, zero-latency fabric, or
//!   `shard_stacks = 1`): the plan lowers back to the sequential engine
//!   and the rendered JSON must stay byte-identical, shard keys absent.

use coda::config::SystemConfig;
use coda::multiprog::MixPlacement;
use coda::net::TopologyKind;
use coda::sched::{FairnessPolicy, Policy};
use coda::session::{Report, Session};
use coda::spec::{
    ArrivalKind, ArrivalSpec, Baselines, ExperimentSpec, TopologySpec, WorkloadSel,
};
use coda::stats::RunReport;
use coda::trace::{Access, BlockTrace, Category, KernelTrace, ObjectDesc};
use coda::workloads::BuiltWorkload;

/// A synthetic multi-block kernel: `blocks` thread-blocks striding over a
/// 64 KiB object with a mix of loads and stores. Block-exclusive so the
/// CGP-local placement makes each app's traffic fully stack-private.
fn workload(name: &'static str, blocks: u32) -> BuiltWorkload {
    let blocks = (0..blocks)
        .map(|b| BlockTrace {
            block_id: b,
            accesses: (0..16u64)
                .map(|i| Access {
                    obj: 0,
                    offset: ((b as u64 * 41 + i * 7) % 1024) * 64,
                    write: i % 3 == 0,
                })
                .collect(),
        })
        .collect();
    BuiltWorkload {
        name,
        category: Category::BlockExclusive,
        trace: KernelTrace {
            name: name.into(),
            threads_per_block: 1,
            objects: vec![ObjectDesc {
                name: "buf".into(),
                bytes: 64 << 10,
            }],
            blocks,
        },
        ir: None,
        env: coda::analysis::ParamEnv::new(1),
    }
}

/// Four single-home apps (one per default stack) under pinned dispatch.
fn pinned_spec<'a>(
    wls: &'a [BuiltWorkload],
    placement: MixPlacement,
    shard_stacks: &str,
) -> ExperimentSpec<'a> {
    let mut spec = ExperimentSpec::pinned(
        wls.iter().map(WorkloadSel::Prebuilt).collect(),
        placement,
    );
    spec.output.baselines = Baselines::None;
    spec.overrides
        .push(("shard_stacks".into(), shard_stacks.into()));
    spec
}

fn run(cfg: SystemConfig, spec: ExperimentSpec) -> Report {
    Session::new(cfg, spec).unwrap().run().unwrap()
}

fn assert_close(a: f64, b: f64, rel: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1e-12);
    assert!(
        (a - b).abs() / denom <= rel,
        "{what}: {a} vs {b} beyond rel {rel}"
    );
}

/// The fields the stack-private regime promises bit-exact.
fn assert_bit_exact(seq: &RunReport, shd: &RunReport) {
    assert_eq!(seq.cycles.to_bits(), shd.cycles.to_bits(), "cycles");
    assert_eq!(seq.accesses, shd.accesses, "access counts");
    assert_eq!(seq.stack_bytes, shd.stack_bytes, "stack bytes");
    assert_eq!(seq.remote_bytes, shd.remote_bytes, "remote bytes");
    assert_eq!(
        seq.tlb_hit_rate.to_bits(),
        shd.tlb_hit_rate.to_bits(),
        "tlb hit rate"
    );
    assert_eq!(
        seq.row_hit_rate.to_bits(),
        shd.row_hit_rate.to_bits(),
        "row hit rate"
    );
    assert_eq!(seq.app_cycles.len(), shd.app_cycles.len());
    for (i, (a, b)) in seq.app_cycles.iter().zip(&shd.app_cycles).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "app_cycles[{i}]");
    }
    // Same addends, per-shard partial-sum order: reassociation noise only.
    assert_close(seq.mean_mem_latency, shd.mean_mem_latency, 1e-9, "latency");
}

/// Stack-private CGP mix: four shards, no messages, bit-exact reports.
#[test]
fn pinned_cgp_sharded_is_bit_exact() {
    let wls: Vec<_> = ["a", "b", "c", "d"]
        .iter()
        .map(|&n| workload(n, 24))
        .collect();
    let cfg = SystemConfig::test_small();
    let seq = run(cfg.clone(), pinned_spec(&wls, MixPlacement::CgpLocal, "1"));
    let shd = run(cfg.clone(), pinned_spec(&wls, MixPlacement::CgpLocal, "4"));
    assert_eq!(shd.run.shard_stacks, 4, "the shard plan must engage");
    assert!(shd.run.shard_windows >= 1);
    assert_eq!(
        shd.run.shard_msgs, 0,
        "stack-private traffic must produce no cross-shard messages"
    );
    assert_eq!(seq.run.shard_stacks, 0, "sequential run must stay unsharded");
    assert_bit_exact(&seq.run, &shd.run);

    // `shard_stacks = 0` (one shard per stack, capped by the machine's
    // parallelism) must agree too, whether or not it engages here.
    let auto = run(cfg, pinned_spec(&wls, MixPlacement::CgpLocal, "0"));
    assert_bit_exact(&seq.run, &auto.run);
}

/// Remote-heavy FGP mix: messages flow, counts stay exact, time agrees
/// statistically.
#[test]
fn pinned_fgp_sharded_matches_statistically() {
    let wls: Vec<_> = ["a", "b", "c", "d"]
        .iter()
        .map(|&n| workload(n, 24))
        .collect();
    let cfg = SystemConfig::test_small();
    let seq = run(cfg.clone(), pinned_spec(&wls, MixPlacement::FgpOnly, "1"));
    let shd = run(cfg, pinned_spec(&wls, MixPlacement::FgpOnly, "4"));
    assert_eq!(shd.run.shard_stacks, 4, "the shard plan must engage");
    assert!(
        shd.run.shard_msgs > 0,
        "FGP interleaving must cross shard boundaries"
    );
    assert!(seq.run.accesses.remote > 0, "the mix must be remote-heavy");
    // Placement decides where every access lands — invariant under
    // sharding.
    assert_eq!(seq.run.accesses, shd.run.accesses, "access counts");
    assert_eq!(seq.run.stack_bytes, shd.run.stack_bytes, "stack bytes");
    assert_eq!(seq.run.remote_bytes, shd.run.remote_bytes, "remote bytes");
    // Timing: event interleavings may differ on contended-resource ties,
    // so cycles agree within tolerance rather than bit-exactly.
    assert_close(seq.run.cycles, shd.run.cycles, 0.10, "cycles");
    assert_close(
        seq.run.mean_mem_latency,
        shd.run.mean_mem_latency,
        0.25,
        "mean latency",
    );
}

/// Degenerate lowering: a 1-stack system and a zero-latency fabric must
/// fall back to the sequential engine — byte-identical JSON, no shard
/// keys — no matter what `shard_stacks` asks for.
#[test]
fn degenerate_configs_render_byte_identical_json() {
    // One stack: nothing to partition.
    let wls = vec![workload("solo", 24)];
    let mut base = pinned_spec(&wls, MixPlacement::CgpLocal, "1");
    base.overrides.push(("num_stacks".into(), "1".into()));
    let mut asked = pinned_spec(&wls, MixPlacement::CgpLocal, "4");
    asked.overrides.push(("num_stacks".into(), "1".into()));
    let cfg = SystemConfig::test_small();
    let a = run(cfg.clone(), base).to_json().render();
    let b = run(cfg.clone(), asked).to_json().render();
    assert_eq!(a, b, "1-stack runs must not depend on shard_stacks");
    assert!(!a.contains("shard_stacks"), "no shard keys when sequential");

    // Zero hop latency: lookahead collapses to 0, so the conservative
    // window cannot advance — the plan must refuse and lower back.
    let wls: Vec<_> = ["a", "b", "c", "d"]
        .iter()
        .map(|&n| workload(n, 12))
        .collect();
    let mut base = pinned_spec(&wls, MixPlacement::FgpOnly, "1");
    base.topology = Some(TopologySpec {
        hop_latency_ns: Some(0.0),
        ..TopologySpec::new(TopologyKind::Ring)
    });
    let mut asked = pinned_spec(&wls, MixPlacement::FgpOnly, "4");
    asked.topology = base.topology;
    let a = run(cfg.clone(), base).to_json().render();
    let b = run(cfg, asked).to_json().render();
    assert_eq!(a, b, "zero-lookahead fabrics must stay sequential");
    assert!(!a.contains("shard_windows"));
}

/// Time-shared (shared-dispatch) mix, two apps per stack: the sharded
/// run restricts each shard to the sequential dispatch of its own
/// stacks, so a stack-private mix stays bit-exact even with SM
/// time-sharing and staggered arrivals.
#[test]
fn shared_dispatch_sharded_preserves_per_app_results() {
    let wls: Vec<_> = ["a", "b", "c", "d", "e", "f", "g", "h"]
        .iter()
        .map(|&n| workload(n, 12))
        .collect();
    let launches: Vec<_> = wls
        .iter()
        .enumerate()
        .map(|(i, w)| (WorkloadSel::Prebuilt(w), 50.0 * i as f64))
        .collect();
    let mk = |shard_stacks: &str| {
        let mut spec = ExperimentSpec::shared(
            launches.clone(),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.output.baselines = Baselines::None;
        spec.overrides
            .push(("shard_stacks".into(), shard_stacks.into()));
        spec
    };
    let cfg = SystemConfig::test_small();
    let seq = run(cfg.clone(), mk("1"));
    let shd = run(cfg, mk("4"));
    assert_eq!(shd.run.shard_stacks, 4, "the shard plan must engage");
    assert_eq!(seq.run.accesses, shd.run.accesses, "access counts");
    assert_eq!(seq.run.app_cycles.len(), 8);
    for (i, (a, b)) in seq
        .run
        .app_cycles
        .iter()
        .zip(&shd.run.app_cycles)
        .enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "app_cycles[{i}]");
    }
    assert_eq!(seq.run.cycles.to_bits(), shd.run.cycles.to_bits());
    // Per-source rows carry the same per-app response times.
    for (s, p) in seq.sources.iter().zip(&shd.sources) {
        assert_eq!(s.cycles.to_bits(), p.cycles.to_bits(), "source cycles");
    }
}

/// Open-loop service mode: requests are dealt round-robin across shards
/// by arrival sequence number, so offered/completed totals and the
/// response-time distribution close exactly against the request cap.
#[test]
fn sharded_service_request_accounting_is_exact() {
    let wl = workload("svc", 2);
    let mk = |shard_stacks: &str| {
        let mut spec = ExperimentSpec::shared(
            vec![(WorkloadSel::Prebuilt(&wl), 0.0)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.arrivals = Some(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![400.0],
            requests: Some(4_000),
            ..ArrivalSpec::default()
        });
        spec.overrides
            .push(("shard_stacks".into(), shard_stacks.into()));
        spec
    };
    let cfg = SystemConfig::test_small();
    let seq = run(cfg.clone(), mk("1"));
    let shd = run(cfg, mk("4"));
    assert_eq!(shd.run.shard_stacks, 4, "the shard plan must engage");
    let ss = seq.run.service.as_ref().expect("service stats");
    let ps = shd.run.service.as_ref().expect("service stats");
    assert_eq!(ss.requests_offered, 4_000);
    assert_eq!(ps.requests_offered, 4_000, "residue classes must partition");
    assert_eq!(ps.requests_completed, 4_000);
    assert_eq!(ps.requests_incomplete, 0);
    // Per-request work is placement-determined, so counts stay exact.
    assert_eq!(seq.run.accesses, shd.run.accesses, "access counts");
    assert!(ps.mean_response > 0.0);
    assert!(ps.p50_response <= ps.p99_response);
    assert!(ps.p99_response <= ps.max_response);
}
