//! Differential suite for the experiment-API redesign: every legacy entry
//! point is now a wrapper that constructs an `ExperimentSpec` and lowers
//! it through `Session`, and each must be **cycle-identical (bit-exact
//! f64)** to its frozen pre-redesign implementation (`oracle.rs`) for
//! mechanisms × workloads × both DRAM backends. A final test proves the
//! spec *file* path (`coda run <spec.toml>`) reproduces the wrapper
//! reports from TOML alone.

mod oracle;

use coda::config::{MemBackendKind, SystemConfig};
use coda::coordinator::{Coordinator, Mechanism};
use coda::multiprog::{
    run_hostmix, run_mix, run_multi, KernelLaunch, Mix, MixPlacement, MultiMix,
};
use coda::placement::{cgp_only_plan, PlacementPlan};
use coda::sched::{FairnessPolicy, Policy};
use coda::session;
use coda::sim::map_objects;
use coda::spec::ExperimentSpec;
use coda::stats::RunReport;
use coda::workloads::suite;

const BACKENDS: [MemBackendKind; 2] =
    [MemBackendKind::FixedLatency, MemBackendKind::BankLevel];

fn cfg_for(backend: MemBackendKind) -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.mem_backend = backend;
    c
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Field-by-field comparison of **everything** a `RunReport` carries; f64
/// fields compare bit-exactly — the redesign must not move a single f64
/// operation, relabel a mechanism, or drop a counter.
fn assert_reports_identical(new: &RunReport, old: &RunReport, what: &str) {
    assert_eq!(new.workload, old.workload, "{what}: workload label");
    assert_eq!(new.mechanism, old.mechanism, "{what}: mechanism label");
    assert_eq!(new.cycles.to_bits(), old.cycles.to_bits(), "{what}: cycles");
    assert_eq!(new.accesses, old.accesses, "{what}: access counts");
    assert_eq!(new.stack_bytes, old.stack_bytes, "{what}: stack bytes");
    assert_eq!(new.remote_bytes, old.remote_bytes, "{what}: remote bytes");
    assert_eq!(
        new.mean_mem_latency.to_bits(),
        old.mean_mem_latency.to_bits(),
        "{what}: latency"
    );
    assert_eq!(
        new.tlb_hit_rate.to_bits(),
        old.tlb_hit_rate.to_bits(),
        "{what}: tlb"
    );
    assert_eq!(
        new.row_hit_rate.to_bits(),
        old.row_hit_rate.to_bits(),
        "{what}: row hit rate"
    );
    assert_eq!(new.mem_backend, old.mem_backend, "{what}: backend label");
    assert_eq!(new.bank_conflicts, old.bank_conflicts, "{what}: conflicts");
    assert_eq!(
        new.refresh_stalls, old.refresh_stalls,
        "{what}: refresh stalls"
    );
    assert_eq!(new.cgp_pages, old.cgp_pages, "{what}: cgp pages");
    assert_eq!(new.fgp_pages, old.fgp_pages, "{what}: fgp pages");
    assert_eq!(
        new.migrated_pages, old.migrated_pages,
        "{what}: migrated pages"
    );
    assert_eq!(
        bits(&new.app_cycles),
        bits(&old.app_cycles),
        "{what}: app cycles"
    );
    assert_eq!(
        bits(&new.app_slowdown),
        bits(&old.app_slowdown),
        "{what}: app slowdown"
    );
    assert_eq!(
        new.weighted_speedup.to_bits(),
        old.weighted_speedup.to_bits(),
        "{what}: weighted speedup"
    );
    assert_eq!(
        new.host_cycles.to_bits(),
        old.host_cycles.to_bits(),
        "{what}: host cycles"
    );
    assert_eq!(
        new.host_slowdown.to_bits(),
        old.host_slowdown.to_bits(),
        "{what}: host slowdown"
    );
    assert_eq!(
        new.ndp_slowdown.to_bits(),
        old.ndp_slowdown.to_bits(),
        "{what}: ndp slowdown"
    );
    assert_eq!(new.host_bytes, old.host_bytes, "{what}: host bytes");
    assert_eq!(
        new.host_ddr_bytes, old.host_ddr_bytes,
        "{what}: host ddr bytes"
    );
    assert_eq!(
        new.host_port_stalls, old.host_port_stalls,
        "{what}: host port stalls"
    );
    assert_eq!(
        new.host_bw_share.to_bits(),
        old.host_bw_share.to_bits(),
        "{what}: host bw share"
    );
    // Belt and braces: the rendered JSON must be byte-identical too.
    assert_eq!(
        coda::report::Json::from(new).render(),
        coda::report::Json::from(old).render(),
        "{what}: JSON"
    );
}

/// `Coordinator::run` (now a spec wrapper) vs the frozen coordinator
/// pipeline, for every mechanism under both backends. HS3D exercises the
/// §6.4 no-degradation fallback inside the lowering.
#[test]
fn coordinator_run_matches_frozen_oracle() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let coord = Coordinator::new(cfg.clone());
        for name in ["PR", "KM", "HS3D"] {
            let wl = suite::build(name, &cfg).unwrap();
            for mech in Mechanism::ALL {
                let new = coord.run(&wl, mech).unwrap();
                let old = oracle::coordinator_run(&cfg, &wl, mech);
                let what = format!("run[{name}]/{}/{}", mech.name(), cfg.mem_backend);
                assert_reports_identical(&new, &old, &what);
            }
        }
    }
}

/// `multiprog::run_mix` (pinned dispatch) vs the frozen implementation.
#[test]
fn run_mix_matches_frozen_oracle() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let c = suite::build("DC", &cfg).unwrap();
        let d = suite::build("HS", &cfg).unwrap();
        let mixes: [Vec<&coda::workloads::BuiltWorkload>; 2] =
            [vec![&a, &b, &c, &d], vec![&a, &c]];
        for apps in &mixes {
            for placement in [MixPlacement::FgpOnly, MixPlacement::CgpLocal] {
                let mix = Mix { apps: apps.clone() };
                let (times_new, rep_new) = run_mix(&cfg, &mix, placement).unwrap();
                let (times_old, rep_old) =
                    oracle::run_mix(&cfg, apps, placement).unwrap();
                let what = format!(
                    "mix[{}]/{placement:?}/{}",
                    rep_new.workload, cfg.mem_backend
                );
                assert_eq!(bits(&times_new), bits(&times_old), "{what}: app times");
                assert_reports_identical(&rep_new, &rep_old, &what);
            }
        }
    }
}

/// `multiprog::run_multi` (shared dispatch + solo baselines) vs the
/// frozen implementation: oversubscribed, staggered, per fairness policy.
#[test]
fn run_multi_matches_frozen_oracle() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let built: Vec<_> = ["NN", "KM", "DC", "HS", "NN"]
            .iter()
            .map(|n| suite::build(n, &cfg).unwrap())
            .collect();
        let launches: Vec<(&coda::workloads::BuiltWorkload, f64)> = built
            .iter()
            .enumerate()
            .map(|(i, b)| (&**b, i as f64 * 3000.0))
            .collect();
        for fairness in [FairnessPolicy::RoundRobin, FairnessPolicy::LeastIssued] {
            let mix = MultiMix {
                launches: launches
                    .iter()
                    .map(|&(app, arrival)| KernelLaunch { app, arrival })
                    .collect(),
            };
            let new = run_multi(
                &cfg,
                &mix,
                MixPlacement::CgpLocal,
                Policy::Affinity,
                fairness,
            )
            .unwrap();
            let old = oracle::run_multi(
                &cfg,
                &launches,
                MixPlacement::CgpLocal,
                Policy::Affinity,
                fairness,
            )
            .unwrap();
            let what = format!("multi/{fairness}/{}", cfg.mem_backend);
            assert_reports_identical(&new, &old, &what);
        }
    }
}

/// `multiprog::run_hostmix` vs the frozen implementation, covering the
/// full co-run, host-alone, and the zero-intensity degenerate case.
#[test]
fn run_hostmix_matches_frozen_oracle() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let h = suite::build("DC", &cfg).unwrap();
        let launches: Vec<(&coda::workloads::BuiltWorkload, f64)> =
            vec![(&a, 0.0), (&b, 2000.0)];
        let mut zero_intensity = cfg.clone();
        zero_intensity.host_mlp = 0;
        let check = |label: &str,
                     ls: &[(&coda::workloads::BuiltWorkload, f64)],
                     case_cfg: &SystemConfig| {
            let mix = MultiMix {
                launches: ls
                    .iter()
                    .map(|&(app, arrival)| KernelLaunch { app, arrival })
                    .collect(),
            };
            let new = run_hostmix(
                case_cfg,
                &mix,
                Some(&h),
                MixPlacement::CgpLocal,
                Policy::Affinity,
                FairnessPolicy::Fcfs,
            )
            .unwrap();
            let old = oracle::run_hostmix(
                case_cfg,
                ls,
                Some(&h),
                MixPlacement::CgpLocal,
                Policy::Affinity,
                FairnessPolicy::Fcfs,
            )
            .unwrap();
            let what = format!("hostmix[{label}]/{}", case_cfg.mem_backend);
            assert_reports_identical(&new, &old, &what);
        };
        check("corun", &launches, &cfg);
        check("host-alone", &[], &cfg);
        check("zero-intensity", &launches, &zero_intensity);

        // host = None is still a hostmix-flavored run (label + degenerate
        // slowdowns), not a run_multi.
        let mix = MultiMix {
            launches: vec![KernelLaunch {
                app: &a,
                arrival: 0.0,
            }],
        };
        let new = run_hostmix(
            &cfg,
            &mix,
            None,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        let old = oracle::run_hostmix(
            &cfg,
            &[(&a, 0.0)],
            None,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        assert_reports_identical(&new, &old, &format!("hostmix[no-host]/{}", cfg.mem_backend));
    }
}

/// `host::run_host_sweep` (external layout) vs the frozen implementation,
/// under both the FGP and CGP layouts it is historically called with.
#[test]
fn host_sweep_matches_frozen_oracle() {
    for backend in BACKENDS {
        let cfg = cfg_for(backend);
        let wl = suite::build("NN", &cfg).unwrap();
        let plans = [
            ("fgp", PlacementPlan::all_fgp(wl.trace.objects.len())),
            ("cgp", cgp_only_plan(wl.trace.objects.len(), &cfg)),
        ];
        for (label, plan) in &plans {
            let (mut vm_new, bases_new, _, _) =
                map_objects(&cfg, &wl.trace, plan).unwrap();
            let new = coda::host::run_host_sweep(&cfg, &wl.trace, &mut vm_new, &bases_new);
            let (mut vm_old, bases_old, _, _) =
                map_objects(&cfg, &wl.trace, plan).unwrap();
            let old = oracle::host_sweep(&cfg, &wl.trace, &mut vm_old, &bases_old);
            let what = format!("host-sweep[{label}]/{}", cfg.mem_backend);
            assert_reports_identical(&new, &old, &what);
        }
    }
}

/// The acceptance check for `coda run <spec.toml>`: a spec parsed from
/// TOML text alone reproduces the wrapper (and hence pre-redesign)
/// reports bit-exactly — the CLI commands are just builders for the same
/// specs.
#[test]
fn toml_specs_reproduce_legacy_cli_reports() {
    let cfg = SystemConfig::test_small();

    // `coda run NN --mechanism coda`.
    let spec = ExperimentSpec::from_toml_str(
        "[experiment]\ndispatch = kernel\n[[kernel]]\nworkload = NN\nmechanism = coda\n",
    )
    .unwrap();
    let from_file = session::run_spec(&cfg, &spec).unwrap().remove(0);
    let wl = suite::build("NN", &cfg).unwrap();
    let direct = Coordinator::new(cfg.clone()).run(&wl, Mechanism::Coda).unwrap();
    assert_reports_identical(&from_file.run, &direct, "spec-file run");

    // `coda mix NN,KM --stagger 2000 --fairness rr`.
    let spec = ExperimentSpec::from_toml_str(
        "[experiment]\ndispatch = shared\nplacement = cgp\npolicy = affinity\n\
         fairness = rr\n[output]\nbaselines = solo\n\
         [[kernel]]\nworkload = NN\narrival = 0\n\
         [[kernel]]\nworkload = KM\narrival = 2000\n",
    )
    .unwrap();
    let from_file = session::run_spec(&cfg, &spec).unwrap().remove(0);
    let a = suite::build("NN", &cfg).unwrap();
    let b = suite::build("KM", &cfg).unwrap();
    let mix = MultiMix {
        launches: vec![
            KernelLaunch {
                app: &a,
                arrival: 0.0,
            },
            KernelLaunch {
                app: &b,
                arrival: 2000.0,
            },
        ],
    };
    let direct = run_multi(
        &cfg,
        &mix,
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::RoundRobin,
    )
    .unwrap();
    assert_reports_identical(&from_file.run, &direct, "spec-file mix");

    // `coda hostmix NN --host KM --host-mlp 16`.
    let spec = ExperimentSpec::from_toml_str(
        "[experiment]\ndispatch = shared\n[output]\nbaselines = host-split\n\
         [[kernel]]\nworkload = NN\n[host]\nworkload = KM\nmlp = 16\n",
    )
    .unwrap();
    let from_file = session::run_spec(&cfg, &spec).unwrap().remove(0);
    let mut host_cfg = cfg.clone();
    host_cfg.host_mlp = 16;
    let mix = MultiMix {
        launches: vec![KernelLaunch {
            app: &a,
            arrival: 0.0,
        }],
    };
    let direct = run_hostmix(
        &host_cfg,
        &mix,
        Some(&b),
        MixPlacement::CgpLocal,
        Policy::Affinity,
        FairnessPolicy::Fcfs,
    )
    .unwrap();
    assert_reports_identical(&from_file.run, &direct, "spec-file hostmix");
}
