//! **Frozen** pre-redesign implementations of every legacy entry point,
//! copied verbatim from `coordinator.rs` / `multiprog.rs` / `host.rs` as
//! they stood before the `ExperimentSpec` → `Session` API redesign.
//!
//! Differential-testing convention (docs/ARCHITECTURE.md): these oracles
//! must never be modernized or deduplicated against the code under test —
//! their whole value is that they cannot drift with it. `main.rs` asserts
//! the spec-based wrappers are cycle-identical (bit-exact f64) to these
//! copies for mechanisms × workloads × both DRAM backends.

use coda::addr::VirtualAddress;
use coda::analysis::{analyze_kernel, profile_trace, ObjectPattern};
use coda::config::SystemConfig;
use coda::coordinator::Mechanism;
use coda::engine::{
    AppCtx, BlockRef, BlockSource, Engine, EngineOptions, EngineRaw, HostStream,
};
use coda::gpu::{Sm, Topology};
use coda::multiprog::MixPlacement;
use coda::placement::{self, PlacementPlan};
use coda::sched::{affinity_stack, FairnessPolicy, Policy};
use coda::sim::{map_objects, KernelRun};
use coda::stats::{self, RunReport};
use coda::trace::KernelTrace;
use coda::vm::VirtualMemory;
use coda::workloads::BuiltWorkload;
use std::collections::{HashMap, VecDeque};

// ---------------------------------------------------------------------------
// Coordinator::run (single kernel).
// ---------------------------------------------------------------------------

fn plan_for(cfg: &SystemConfig, wl: &BuiltWorkload, mech: Mechanism) -> PlacementPlan {
    let n = wl.trace.objects.len();
    match mech {
        Mechanism::FgpOnly | Mechanism::FgpAffinity => PlacementPlan::all_fgp(n),
        Mechanism::CgpOnly => placement::cgp_only_plan(n, cfg),
        Mechanism::CgpFta => placement::fta_plan(&wl.trace, cfg),
        Mechanism::MigrationFta => placement::migration_fta_plan(n),
        Mechanism::Coda | Mechanism::CodaStealing => {
            let compile: HashMap<u16, ObjectPattern> = wl
                .ir
                .as_ref()
                .map(|ir| analyze_kernel(ir, &wl.env))
                .unwrap_or_default();
            let profile =
                profile_trace(&wl.trace, cfg.page_size, |b| affinity_stack(b, cfg));
            placement::coda_plan(n, &compile, &profile, cfg)
        }
    }
}

fn localizable_traffic(wl: &BuiltWorkload, plan: &PlacementPlan) -> f64 {
    let mut per_obj = vec![0u64; wl.trace.objects.len()];
    for b in &wl.trace.blocks {
        for a in &b.accesses {
            per_obj[a.obj as usize] += 1;
        }
    }
    let total: u64 = per_obj.iter().sum();
    let localized: u64 = per_obj
        .iter()
        .enumerate()
        .filter(|(o, _)| !matches!(plan.per_object[*o], placement::Placement::Fgp))
        .map(|(_, n)| *n)
        .sum();
    if total == 0 {
        0.0
    } else {
        localized as f64 / total as f64
    }
}

/// Frozen copy of the pre-spec `Coordinator::run`.
pub fn coordinator_run(
    cfg: &SystemConfig,
    wl: &BuiltWorkload,
    mech: Mechanism,
) -> RunReport {
    let mut plan = plan_for(cfg, wl, mech);
    let mut policy = mech.policy();
    if matches!(mech, Mechanism::Coda | Mechanism::CodaStealing)
        && localizable_traffic(wl, &plan) < 0.05
    {
        plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        policy = Policy::Baseline;
    }
    let (mut vm, bases, cgp_pages, fgp_pages) =
        map_objects(cfg, &wl.trace, &plan).unwrap();
    let mut report = KernelRun {
        cfg,
        trace: &wl.trace,
        vm: &mut vm,
        obj_base: &bases,
        policy,
        migrate_on_first_touch: plan.migrate_on_first_touch,
    }
    .run();
    report.mechanism = mech.name().into();
    report.cgp_pages = cgp_pages;
    report.fgp_pages = fgp_pages;
    report
}

// ---------------------------------------------------------------------------
// multiprog (pinned mix, multi-kernel, hostmix).
// ---------------------------------------------------------------------------

#[inline]
fn home_of(app_idx: usize, cfg: &SystemConfig) -> usize {
    app_idx % cfg.num_stacks
}

fn map_mix(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    placement: MixPlacement,
) -> coda::Result<(VirtualMemory, Vec<Vec<VirtualAddress>>)> {
    let mut vm = VirtualMemory::new(cfg);
    let mut app_bases: Vec<Vec<VirtualAddress>> = Vec::new();
    for (i, app) in apps.iter().enumerate() {
        let home = home_of(i, cfg);
        let mut bases = Vec::new();
        for obj in &app.trace.objects {
            let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
            let base = match placement {
                MixPlacement::FgpOnly => vm.map_fgp(pages)?,
                MixPlacement::CgpLocal => vm.map_cgp(pages, |_| home)?,
            };
            bases.push(base);
        }
        app_bases.push(bases);
    }
    Ok((vm, app_bases))
}

struct MixSource {
    next_block: Vec<usize>,
    num_blocks: Vec<usize>,
}

impl BlockSource for MixSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        for app in 0..self.num_blocks.len() {
            let sms: Vec<usize> = topo.sms_of_stack(app).map(|s| s.id).collect();
            let capacity = sms.len() * topo.blocks_per_sm;
            for slot in 0..capacity {
                if self.next_block[app] >= self.num_blocks[app] {
                    break;
                }
                let b = self.next_block[app];
                self.next_block[app] += 1;
                place(
                    sms[slot % sms.len()],
                    slot / sms.len(),
                    BlockRef {
                        app: app as u32,
                        block: b as u32,
                    },
                );
            }
        }
    }

    fn refill(&mut self, _sm: Sm, retired: Option<BlockRef>, _now: f64) -> Option<BlockRef> {
        let app = retired?.app as usize;
        if self.next_block[app] < self.num_blocks[app] {
            let b = self.next_block[app];
            self.next_block[app] += 1;
            Some(BlockRef {
                app: app as u32,
                block: b as u32,
            })
        } else {
            None
        }
    }
}

/// Frozen copy of the pre-spec `multiprog::run_mix`.
pub fn run_mix(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    placement: MixPlacement,
) -> coda::Result<(Vec<f64>, RunReport)> {
    anyhow::ensure!(apps.len() <= cfg.num_stacks, "too many apps");
    let (mut vm, app_bases) = map_mix(cfg, apps, placement)?;
    let app_ctxs: Vec<AppCtx<'_>> = apps
        .iter()
        .zip(&app_bases)
        .map(|(a, b)| AppCtx {
            trace: &a.trace,
            obj_base: b.as_slice(),
        })
        .collect();
    let mut source = MixSource {
        next_block: vec![0; apps.len()],
        num_blocks: apps.iter().map(|a| a.trace.blocks.len()).collect(),
    };
    let raw = Engine {
        cfg,
        apps: app_ctxs,
        vm: &mut vm,
        opts: EngineOptions {
            l2_filter: false,
            migrate_on_first_touch: false,
        },
        host: None,
    }
    .run(&mut source);
    let mut report = raw.to_report(
        cfg,
        apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+"),
    );
    report.mechanism = format!("{placement:?}");
    report.app_cycles = raw.app_end.clone();
    Ok((raw.app_end, report))
}

struct MultiKernelSource {
    queues: Vec<VecDeque<u32>>,
    arrival: Vec<f64>,
    home: Vec<usize>,
    policy: Policy,
    fairness: FairnessPolicy,
    issued: Vec<u64>,
    rr_cursor: usize,
}

impl MultiKernelSource {
    fn new(
        launches: &[(usize, f64)],
        cfg: &SystemConfig,
        policy: Policy,
        fairness: FairnessPolicy,
        only_app: Option<usize>,
    ) -> Self {
        let queues = launches
            .iter()
            .enumerate()
            .map(|(i, &(n, _))| {
                if only_app.is_some_and(|o| o != i) {
                    VecDeque::new()
                } else {
                    (0..n as u32).collect()
                }
            })
            .collect();
        Self {
            queues,
            arrival: launches.iter().map(|&(_, t)| t).collect(),
            home: (0..launches.len()).map(|i| home_of(i, cfg)).collect(),
            policy,
            fairness,
            issued: vec![0; launches.len()],
            rr_cursor: 0,
        }
    }

    fn eligible(&self, stack: usize, now: f64) -> Vec<usize> {
        let arrived: Vec<usize> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].is_empty() && self.arrival[i] <= now)
            .collect();
        match self.policy {
            Policy::Baseline => arrived,
            Policy::Affinity => arrived
                .into_iter()
                .filter(|&i| self.home[i] == stack)
                .collect(),
            Policy::AffinityStealing => {
                let homed: Vec<usize> = arrived
                    .iter()
                    .copied()
                    .filter(|&i| self.home[i] == stack)
                    .collect();
                if homed.is_empty() {
                    arrived
                } else {
                    homed
                }
            }
        }
    }

    fn pick(&mut self, stack: usize, now: f64) -> Option<BlockRef> {
        let elig = self.eligible(stack, now);
        if elig.is_empty() {
            return None;
        }
        let app = match self.fairness {
            FairnessPolicy::Fcfs => elig.into_iter().min_by(|&a, &b| {
                self.arrival[a]
                    .partial_cmp(&self.arrival[b])
                    .expect("arrival times are finite")
                    .then(a.cmp(&b))
            })?,
            FairnessPolicy::RoundRobin => {
                let n = self.queues.len();
                (1..=n)
                    .map(|k| (self.rr_cursor + k) % n)
                    .find(|i| elig.contains(i))?
            }
            FairnessPolicy::LeastIssued => {
                elig.into_iter().min_by_key(|&i| (self.issued[i], i))?
            }
        };
        self.rr_cursor = app;
        self.issued[app] += 1;
        let block = self.queues[app].pop_front()?;
        Some(BlockRef {
            app: app as u32,
            block,
        })
    }
}

impl BlockSource for MultiKernelSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        for slot in 0..topo.blocks_per_sm {
            for sm in &topo.sms {
                if let Some(br) = self.pick(sm.stack, 0.0) {
                    place(sm.id, slot, br);
                }
            }
        }
    }

    fn refill(&mut self, sm: Sm, _retired: Option<BlockRef>, now: f64) -> Option<BlockRef> {
        self.pick(sm.stack, now)
    }

    fn next_arrival_after(&self, now: f64) -> Option<f64> {
        self.queues
            .iter()
            .zip(&self.arrival)
            .filter(|(q, &t)| !q.is_empty() && t > now)
            .map(|(_, &t)| t)
            .fold(None, |m, t| {
                Some(match m {
                    None => t,
                    Some(m) => m.min(t),
                })
            })
    }
}

#[allow(clippy::too_many_arguments)]
fn run_multi_inner(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    arrivals: &[f64],
    only_app: Option<usize>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> coda::Result<EngineRaw> {
    let (mut vm, app_bases) = map_mix(cfg, apps, placement)?;
    let app_ctxs: Vec<AppCtx<'_>> = apps
        .iter()
        .zip(&app_bases)
        .map(|(a, b)| AppCtx {
            trace: &a.trace,
            obj_base: b.as_slice(),
        })
        .collect();
    let launches: Vec<(usize, f64)> = apps
        .iter()
        .zip(arrivals)
        .map(|(a, &t)| (a.trace.blocks.len(), t))
        .collect();
    let mut source = MultiKernelSource::new(&launches, cfg, policy, fairness, only_app);
    Ok(Engine {
        cfg,
        apps: app_ctxs,
        vm: &mut vm,
        opts: EngineOptions {
            l2_filter: false,
            migrate_on_first_touch: false,
        },
        host: None,
    }
    .run(&mut source))
}

/// Frozen copy of the pre-spec `multiprog::run_multi`.
pub fn run_multi(
    cfg: &SystemConfig,
    launches_in: &[(&BuiltWorkload, f64)],
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> coda::Result<RunReport> {
    let apps: Vec<&BuiltWorkload> = launches_in.iter().map(|&(a, _)| a).collect();
    let arrivals: Vec<f64> = launches_in.iter().map(|&(_, t)| t).collect();
    for (i, &t) in arrivals.iter().enumerate() {
        anyhow::ensure!(
            t >= 0.0 && t.is_finite(),
            "arrival time of app {i} must be a non-negative real, got {t}"
        );
    }
    let shared = run_multi_inner(cfg, &apps, &arrivals, None, placement, policy, fairness)?;
    let zero = vec![0.0; apps.len()];
    let mut solo = Vec::with_capacity(apps.len());
    for i in 0..apps.len() {
        let raw =
            run_multi_inner(cfg, &apps, &zero, Some(i), placement, policy, fairness)?;
        solo.push(raw.app_end[i]);
    }
    let resp: Vec<f64> = (0..apps.len())
        .map(|i| (shared.app_end[i] - arrivals[i]).max(0.0))
        .collect();
    let mut report = shared.to_report(
        cfg,
        apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+"),
    );
    report.mechanism = format!("{placement:?}+{policy:?}+{fairness}");
    report.app_slowdown = stats::per_app_slowdown(&solo, &resp);
    report.weighted_speedup = stats::weighted_speedup(&solo, &resp);
    report.app_cycles = resp;
    Ok(report)
}

/// Frozen copy of the pre-spec `multiprog::run_hostmix`.
pub fn run_hostmix(
    cfg: &SystemConfig,
    launches_in: &[(&BuiltWorkload, f64)],
    host: Option<&BuiltWorkload>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> coda::Result<RunReport> {
    let apps: Vec<&BuiltWorkload> = launches_in.iter().map(|&(a, _)| a).collect();
    let arrivals: Vec<f64> = launches_in.iter().map(|&(_, t)| t).collect();
    for (i, &t) in arrivals.iter().enumerate() {
        anyhow::ensure!(
            t >= 0.0 && t.is_finite(),
            "arrival time of app {i} must be a non-negative real, got {t}"
        );
    }
    anyhow::ensure!(
        host.is_some() || !apps.is_empty(),
        "hostmix needs a host stream, at least one NDP kernel, or both"
    );
    let host_active = host.is_some() && cfg.host_mlp > 0 && cfg.host_passes > 0;

    let (mut vm, app_bases) = map_mix(cfg, &apps, placement)?;
    let host_bases: Vec<VirtualAddress> = match host {
        Some(h) => {
            let mut bases = Vec::with_capacity(h.trace.objects.len());
            for obj in &h.trace.objects {
                let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
                bases.push(vm.map_fgp(pages)?);
            }
            bases
        }
        None => Vec::new(),
    };
    let launches: Vec<(usize, f64)> = apps
        .iter()
        .zip(&arrivals)
        .map(|(a, &t)| (a.trace.blocks.len(), t))
        .collect();

    let exec = |with_ndp: bool, with_host: bool, vm: &mut VirtualMemory| -> EngineRaw {
        let app_ctxs: Vec<AppCtx<'_>> = if with_ndp {
            apps.iter()
                .zip(&app_bases)
                .map(|(a, b)| AppCtx {
                    trace: &a.trace,
                    obj_base: b.as_slice(),
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut source = MultiKernelSource::new(
            if with_ndp { launches.as_slice() } else { &[] },
            cfg,
            policy,
            fairness,
            None,
        );
        let host_stream = if with_host {
            host.map(|h| HostStream {
                trace: &h.trace,
                obj_base: &host_bases,
            })
        } else {
            None
        };
        Engine {
            cfg,
            apps: app_ctxs,
            vm,
            opts: EngineOptions {
                l2_filter: false,
                migrate_on_first_touch: false,
            },
            host: host_stream,
        }
        .run(&mut source)
    };

    let shared = exec(!apps.is_empty(), host_active, &mut vm);
    let both = host_active && !apps.is_empty();
    let ndp_alone = both.then(|| exec(true, false, &mut vm));
    let host_alone = both.then(|| exec(false, true, &mut vm));

    let resp: Vec<f64> = (0..apps.len())
        .map(|i| (shared.app_end[i] - arrivals[i]).max(0.0))
        .collect();
    let n = apps.len();
    let (ndp_slowdown, host_slowdown, app_slowdown, weighted) =
        match (&ndp_alone, &host_alone) {
            (Some(na), Some(ha)) => {
                let resp_alone: Vec<f64> = (0..n)
                    .map(|i| (na.app_end[i] - arrivals[i]).max(0.0))
                    .collect();
                let ndp_sd = if na.end_time > 0.0 {
                    shared.end_time / na.end_time
                } else {
                    1.0
                };
                let host_sd = if ha.host_end > 0.0 {
                    shared.host_end / ha.host_end
                } else {
                    1.0
                };
                (
                    ndp_sd,
                    host_sd,
                    stats::per_app_slowdown(&resp_alone, &resp),
                    stats::weighted_speedup(&resp_alone, &resp),
                )
            }
            _ => (
                if n > 0 { 1.0 } else { 0.0 },
                if host_active { 1.0 } else { 0.0 },
                vec![1.0; n],
                n as f64,
            ),
        };

    let ndp_names = apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+");
    let workload = match (if host_active { host } else { None }, ndp_names.is_empty()) {
        (Some(h), true) => format!("host:{}", h.name),
        (Some(h), false) => format!("{ndp_names}|host:{}", h.name),
        (None, _) => ndp_names,
    };
    let mut report = shared.to_report(cfg, workload);
    report.mechanism = format!("hostmix:{placement:?}+{policy:?}+{fairness}");
    report.app_cycles = resp;
    report.app_slowdown = app_slowdown;
    report.weighted_speedup = weighted;
    report.ndp_slowdown = ndp_slowdown;
    report.host_slowdown = host_slowdown;
    Ok(report)
}

// ---------------------------------------------------------------------------
// host::run_host_sweep.
// ---------------------------------------------------------------------------

struct NoBlocks;

impl BlockSource for NoBlocks {
    fn seed(&mut self, _topo: &Topology, _place: &mut dyn FnMut(usize, usize, BlockRef)) {}

    fn refill(&mut self, _sm: Sm, _retired: Option<BlockRef>, _now: f64) -> Option<BlockRef> {
        None
    }
}

/// Frozen copy of the pre-spec `host::run_host_sweep`.
pub fn host_sweep(
    cfg: &SystemConfig,
    trace: &KernelTrace,
    vm: &mut VirtualMemory,
    obj_base: &[VirtualAddress],
) -> RunReport {
    let raw = Engine {
        cfg,
        apps: Vec::new(),
        vm,
        opts: EngineOptions {
            l2_filter: false,
            migrate_on_first_touch: false,
        },
        host: Some(HostStream { trace, obj_base }),
    }
    .run(&mut NoBlocks);
    let mut report = raw.to_report(cfg, trace.name.clone());
    report.mechanism = "host".into();
    report
}
