//! Property tests for the declarative experiment API: randomized
//! parse/serialize round-trips over the whole spec surface, plus the
//! committed `examples/*.toml` specs — each must parse, round-trip, run,
//! and emit JSON that actually parses.

use coda::config::SystemConfig;
use coda::coordinator::Mechanism;
use coda::multiprog::MixPlacement;
use coda::net::TopologyKind;
use coda::proptest_lite::{run_prop, PropConfig};
use coda::report::validate_json;
use coda::rng::Rng;
use coda::sched::{FairnessPolicy, Policy};
use coda::session;
use coda::spec::{
    ArrivalKind, ArrivalSpec, Baselines, Dispatch, ExperimentSpec, HostSpec, KernelSpec,
    OutputFormat, OutputSpec, SweepSpec, TopologySpec, WorkloadSel,
};
use std::path::PathBuf;

const NAMES: [&str; 6] = ["NN", "KM", "DC", "HS", "PR", "BFS"];

fn pick<T: Copy>(rng: &mut Rng, xs: &[T]) -> T {
    xs[rng.below(xs.len() as u64) as usize]
}

/// Draw a random (syntactically arbitrary, not necessarily runnable)
/// spec over suite-named workloads. Serialization must round-trip every
/// combination, including ones `Session::new` would reject.
fn arbitrary_spec(rng: &mut Rng) -> ExperimentSpec<'static> {
    let mut spec = ExperimentSpec {
        name: rng
            .chance(0.5)
            .then(|| format!("spec-{}", rng.below(1000))),
        dispatch: pick(
            rng,
            &[
                Dispatch::Auto,
                Dispatch::Kernel,
                Dispatch::Pinned,
                Dispatch::Shared,
            ],
        ),
        placement: pick(rng, &[MixPlacement::FgpOnly, MixPlacement::CgpLocal]),
        policy: pick(
            rng,
            &[Policy::Baseline, Policy::Affinity, Policy::AffinityStealing],
        ),
        fairness: rng.chance(0.5).then(|| {
            pick(
                rng,
                &[
                    FairnessPolicy::Fcfs,
                    FairnessPolicy::RoundRobin,
                    FairnessPolicy::LeastIssued,
                ],
            )
        }),
        output: OutputSpec {
            format: pick(rng, &[OutputFormat::Table, OutputFormat::Json]),
            baselines: pick(
                rng,
                &[
                    Baselines::Auto,
                    Baselines::None,
                    Baselines::Solo,
                    Baselines::HostSplit,
                ],
            ),
        },
        ..ExperimentSpec::default()
    };
    for _ in 0..rng.below(4) {
        spec.overrides.push((
            pick(rng, &["seed", "host_mlp", "remote_bw_gbs"]).to_string(),
            rng.below(1000).to_string(),
        ));
    }
    if rng.chance(0.3) {
        spec.sweep = Some(SweepSpec {
            key: "remote_bw_gbs".into(),
            values: (0..1 + rng.below(3))
                .map(|_| (1 + rng.below(256)).to_string())
                .collect(),
        });
    }
    for i in 0..rng.below(4) {
        let mut k = KernelSpec::new(WorkloadSel::Named(pick(rng, &NAMES)));
        // Fractional arrivals exercise exact f64 Display/parse round-trips.
        k.arrival = rng.below(1_000_000) as f64 + if rng.chance(0.5) { 0.25 } else { 0.0 };
        if rng.chance(0.3) {
            k.placement = Some(pick(rng, &[MixPlacement::FgpOnly, MixPlacement::CgpLocal]));
        }
        if rng.chance(0.3) {
            k.mechanism = Some(pick(rng, &Mechanism::ALL));
        }
        if rng.chance(0.3) {
            k.home = Some(i as usize);
        }
        if i > 0 && rng.chance(0.3) {
            // Service-mode DAG edges (syntactic only here — round-trips
            // must hold even without an [arrivals] section).
            k.after = (0..i).filter(|_| rng.chance(0.5)).map(|d| d as usize).collect();
        }
        spec.kernels.push(k);
    }
    if rng.chance(0.3) {
        let kind = pick(
            rng,
            &[ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Trace],
        );
        spec.arrivals = Some(ArrivalSpec {
            kind,
            rate: rng
                .chance(0.7)
                .then(|| (1 + rng.below(100)) as f64 / 1024.0),
            requests: rng.chance(0.7).then(|| 1 + rng.below(1000)),
            duration: rng
                .chance(0.5)
                .then(|| (1 + rng.below(1_000_000)) as f64 + 0.5),
            seed: rng.chance(0.5).then(|| rng.below(u64::MAX)),
            burst: rng.chance(0.5).then(|| 1 + rng.below(16)),
            interarrivals: (0..rng.below(4))
                // Fractional gaps exercise exact f64 Display/parse.
                .map(|_| rng.below(1000) as f64 + 0.25)
                .collect(),
        });
    }
    if rng.chance(0.4) {
        let mut t = TopologySpec::new(pick(
            rng,
            &[
                TopologyKind::FullyConnected,
                TopologyKind::Line,
                TopologyKind::Ring,
                TopologyKind::Mesh2d,
            ],
        ));
        if rng.chance(0.5) {
            t.mesh_cols = Some(rng.below(5) as usize);
        }
        if rng.chance(0.5) {
            // Fractional knobs exercise exact f64 Display/parse round-trips.
            t.hop_latency_ns =
                Some(rng.below(100) as f64 + if rng.chance(0.5) { 0.5 } else { 0.0 });
        }
        if rng.chance(0.5) {
            t.link_bw_gbs = Some((1 + rng.below(256)) as f64);
        }
        if rng.chance(0.5) {
            t.window_cycles = Some((1 + rng.below(65536)) as f64);
        }
        spec.topology = Some(t);
    }
    if rng.chance(0.4) {
        let mut h = HostSpec::new(WorkloadSel::Named(pick(rng, &NAMES)));
        if rng.chance(0.5) {
            h.mlp = Some(1 + rng.below(128) as usize);
        }
        if rng.chance(0.5) {
            h.passes = Some(1 + rng.below(4));
        }
        if rng.chance(0.5) {
            h.ddr_fraction = Some((rng.below(100) as f64) / 100.0);
        }
        spec.host = Some(h);
    }
    spec
}

#[test]
fn spec_toml_round_trip_is_lossless() {
    run_prop(
        PropConfig {
            cases: 128,
            ..PropConfig::default()
        },
        arbitrary_spec,
        |spec| {
            let text = spec.to_toml_string();
            let reparsed = ExperimentSpec::from_toml_str(&text)
                .map_err(|e| format!("serialized spec failed to parse: {e:#}\n{text}"))?;
            if &reparsed != spec {
                return Err(format!(
                    "round trip changed the spec:\n  in: {spec:?}\n out: {reparsed:?}\ntoml:\n{text}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn double_round_trip_is_fixed_point() {
    // serialize(parse(serialize(s))) == serialize(s): the TOML form is
    // canonical, so committed example specs never churn.
    run_prop(
        PropConfig {
            cases: 32,
            ..PropConfig::default()
        },
        |rng| arbitrary_spec(rng).to_toml_string(),
        |text| {
            let once = ExperimentSpec::from_toml_str(text)
                .map_err(|e| format!("parse: {e:#}"))?
                .to_toml_string();
            if &once != text {
                return Err(format!("not canonical:\n--- first\n{text}\n--- second\n{once}"));
            }
            Ok(())
        },
    );
}

fn examples_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("examples")
}

fn example_specs() -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = std::fs::read_dir(examples_dir())
        .expect("examples/ directory")
        .filter_map(|e| {
            let p = e.unwrap().path();
            (p.extension().and_then(|x| x.to_str()) == Some("toml")).then(|| {
                (
                    p.file_name().unwrap().to_string_lossy().into_owned(),
                    std::fs::read_to_string(&p).unwrap(),
                )
            })
        })
        .collect();
    out.sort();
    out
}

#[test]
fn committed_example_specs_parse_and_round_trip() {
    let examples = example_specs();
    assert!(
        examples.len() >= 6,
        "expected one example spec per legacy command, found {}",
        examples.len()
    );
    for (name, text) in &examples {
        let spec = ExperimentSpec::from_toml_str(text)
            .unwrap_or_else(|e| panic!("{name} failed to parse: {e:#}"));
        let reparsed = ExperimentSpec::from_toml_str(&spec.to_toml_string())
            .unwrap_or_else(|e| panic!("{name} round trip failed: {e:#}"));
        assert_eq!(reparsed, spec, "{name} round trip changed the spec");
    }
}

#[test]
fn committed_example_specs_run_and_emit_valid_json() {
    // The in-repo version of the CI spec-smoke job: every committed
    // example runs end to end and its JSON report parses.
    let base = SystemConfig::default();
    for (name, text) in &example_specs() {
        let spec = ExperimentSpec::from_toml_str(text).unwrap();
        let reports = session::run_spec(&base, &spec)
            .unwrap_or_else(|e| panic!("{name} failed to run: {e:#}"));
        assert!(!reports.is_empty(), "{name} produced no reports");
        for r in &reports {
            assert!(r.run.cycles >= 0.0);
            let json = r.to_json().render();
            validate_json(&json)
                .unwrap_or_else(|e| panic!("{name} emitted invalid JSON ({e}): {json}"));
        }
    }
}
