//! Integration tests for the hierarchical address-translation subsystem
//! (`xlate.rs`) at the report level:
//!
//! * A non-degenerate config (`tlb_l1_entries > 0`) reports L1/L2 hit
//!   rates and a nonzero walk-stall share; the degenerate default reports
//!   `None` (its JSON stays byte-identical to the frozen legacy model).
//! * Huge pages cut page walks and walk stalls on a CGP-heavy layout,
//!   while an FGP-interleaved layout stays at base pages (coverage 0).
//! * Time-shared SMs share one TLB across co-scheduled apps by default;
//!   `tlb_flush_on_switch` drops translations at every address-space
//!   switch and must cost hits — with identical access counts.

use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::multiprog::{run_multi, KernelLaunch, MixPlacement, MultiMix};
use coda::placement::{Placement, PlacementPlan};
use coda::sched::{FairnessPolicy, Policy};
use coda::sim::{map_objects, KernelRun};
use coda::stats::RunReport;
use coda::trace::{Access, BlockTrace, Category, KernelTrace, ObjectDesc};
use coda::workloads::{suite, BuiltWorkload};
use std::collections::HashMap;

/// Small hierarchical TLBs over the test config: tight enough that page
/// walks actually happen on every workload below.
fn hier_cfg() -> SystemConfig {
    let mut c = SystemConfig::test_small();
    c.l2_hit_rate = 0.0; // exact access counts
    c.tlb_l1_entries = 8;
    c.tlb_l1_ways = 4;
    c.tlb_l2_entries = 16;
    c.tlb_l2_ways = 8;
    c.validate().unwrap();
    c
}

/// One object; each block scans its own contiguous `pages_per_block`-page
/// slice touching one line per page — a TLB-bound page-stride walk.
fn page_stride_trace(cfg: &SystemConfig, blocks: u32, pages_per_block: u64) -> KernelTrace {
    KernelTrace {
        name: "pagestride".into(),
        threads_per_block: 256,
        objects: vec![ObjectDesc {
            name: "data".into(),
            bytes: blocks as u64 * pages_per_block * cfg.page_size,
        }],
        blocks: (0..blocks)
            .map(|b| BlockTrace {
                block_id: b,
                accesses: (0..pages_per_block)
                    .map(|p| Access {
                        obj: 0,
                        offset: (b as u64 * pages_per_block + p) * cfg.page_size,
                        write: false,
                    })
                    .collect(),
            })
            .collect(),
    }
}

fn run_plan(cfg: &SystemConfig, trace: &KernelTrace, plan: &PlacementPlan) -> RunReport {
    let (mut vm, bases, _, _) = map_objects(cfg, trace, plan).unwrap();
    KernelRun {
        cfg,
        trace,
        vm: &mut vm,
        obj_base: &bases,
        policy: Policy::Baseline,
        migrate_on_first_touch: false,
    }
    .run()
}

/// CGP plan whose chunks span a whole 2 MB frame, so every aligned run of
/// 512 base pages lands on one stack and qualifies for promotion.
fn cgp_2mb_plan() -> PlacementPlan {
    PlacementPlan {
        per_object: vec![Placement::Cgp { chunk_size: 2 << 20 }],
        page_overrides: HashMap::new(),
        migrate_on_first_touch: false,
    }
}

#[test]
fn hierarchical_config_reports_xlate_stats() {
    let cfg = hier_cfg();
    let wl = suite::build("KM", &cfg).unwrap();
    let r = Coordinator::new(cfg).run(&wl, Mechanism::Coda).unwrap();
    let x = r.xlate.expect("hierarchical run must report xlate stats");
    assert!(x.l1_hits + x.l1_misses > 0, "accesses must consult the L1");
    assert!((0.0..=1.0).contains(&x.l1_hit_rate), "{}", x.l1_hit_rate);
    assert!((0.0..=1.0).contains(&x.l2_hit_rate), "{}", x.l2_hit_rate);
    assert!(x.walks > 0, "a 16-entry L2 cannot hold KM's footprint");
    assert_eq!(x.walks, x.l2_misses);
    assert!(x.walk_cycles > 0.0);
    assert!(
        x.walk_stall_share > 0.0,
        "page walks must show up as stall share"
    );
}

#[test]
fn degenerate_config_reports_no_xlate() {
    // The default (tlb_l1_entries = 0) runs the frozen legacy flat-walk
    // model; its reports must not grow an xlate block.
    let cfg = SystemConfig::test_small();
    let wl = suite::build("KM", &cfg).unwrap();
    let r = Coordinator::new(cfg).run(&wl, Mechanism::Coda).unwrap();
    assert!(r.xlate.is_none(), "legacy model must not report xlate stats");
}

/// The §7.2 differential: on a CGP-heavy layout, huge pages collapse each
/// aligned 512-page run into one 2 MB mapping — one TLB entry and a
/// one-level-shorter walk — so walks and walk stalls drop and the run gets
/// faster. FGP-interleaved data must stay at base pages throughout.
#[test]
fn huge_pages_cut_walk_stalls_on_cgp_heavy_layout() {
    let mut off = hier_cfg();
    off.huge_pages = false;
    let mut on = off.clone();
    on.huge_pages = true;

    // 4 blocks x 512 pages = four full 2 MB frames, one per stack.
    let trace = page_stride_trace(&off, 4, 512);
    let r_off = run_plan(&off, &trace, &cgp_2mb_plan());
    let r_on = run_plan(&on, &trace, &cgp_2mb_plan());
    let x_off = r_off.xlate.unwrap();
    let x_on = r_on.xlate.unwrap();

    // Same accesses either way; only the translation machinery differs.
    assert_eq!(r_off.accesses.ndp_total(), r_on.accesses.ndp_total());
    assert_eq!(
        x_off.l1_hits + x_off.l1_misses,
        x_on.l1_hits + x_on.l1_misses
    );

    assert_eq!(x_off.huge_pages, 0);
    assert_eq!(x_off.huge_coverage, 0.0);
    assert_eq!(x_on.huge_pages, 4, "one promoted frame per 2 MB run");
    assert!(x_on.huge_coverage > 0.9, "coverage {}", x_on.huge_coverage);

    assert!(
        x_on.walks < x_off.walks,
        "huge TLB reach must cut walks: {} vs {}",
        x_on.walks,
        x_off.walks
    );
    assert!(x_on.walk_cycles < x_off.walk_cycles);
    assert!(
        r_on.cycles < r_off.cycles,
        "fewer+shorter walks must show in the makespan: {} vs {}",
        r_on.cycles,
        r_off.cycles
    );

    // FGP-interleaved ranges stay at 4 KB even with promotion enabled.
    let r_fgp = run_plan(&on, &trace, &PlacementPlan::all_fgp(1));
    let x_fgp = r_fgp.xlate.unwrap();
    assert_eq!(x_fgp.huge_pages, 0, "FGP pages must never promote");
    assert_eq!(x_fgp.huge_coverage, 0.0);
    assert!(
        x_on.huge_coverage > x_fgp.huge_coverage,
        "CGP-heavy layouts must report higher huge coverage than FGP"
    );
}

/// Two co-scheduled apps whose blocks all hammer the same two pages: the
/// per-SM TLB working set is four pages, so with shared (default) TLBs
/// nearly everything hits after the compulsory misses.
fn hot_page_app(cfg: &SystemConfig, name: &'static str) -> BuiltWorkload {
    let lines_per_page = cfg.page_size / cfg.line_size;
    let accesses: Vec<Access> = (0..64u64)
        .flat_map(|r| {
            [0u64, 1].map(|pg| Access {
                obj: 0,
                offset: pg * cfg.page_size + (r % lines_per_page) * cfg.line_size,
                write: false,
            })
        })
        .collect();
    BuiltWorkload {
        name,
        category: Category::Sharing,
        trace: KernelTrace {
            name: name.into(),
            threads_per_block: 256,
            objects: vec![ObjectDesc {
                name: "hot".into(),
                bytes: 2 * cfg.page_size,
            }],
            blocks: (0..64)
                .map(|b| BlockTrace {
                    block_id: b,
                    accesses: accesses.clone(),
                })
                .collect(),
        },
        ir: None,
        env: coda::analysis::ParamEnv::new(256),
    }
}

/// Time-shared SMs share one TLB across co-scheduled apps by default;
/// `tlb_flush_on_switch` opts into dropping translations at every
/// address-space switch. Both behaviors pinned under `run_multi`: the
/// access totals are identical, but flushing must cost L1 hits.
#[test]
fn tlb_flush_on_switch_costs_hits_under_time_sharing() {
    let base = hier_cfg();
    let apps = [hot_page_app(&base, "hotA"), hot_page_app(&base, "hotB")];
    let run = |flush: bool| {
        let mut cfg = base.clone();
        cfg.tlb_flush_on_switch = flush;
        let mix = MultiMix {
            launches: apps
                .iter()
                .map(|a| KernelLaunch { app: a, arrival: 0.0 })
                .collect(),
        };
        // Baseline policy + round-robin fairness co-locates both apps on
        // every SM, so address-space switches happen constantly.
        run_multi(
            &cfg,
            &mix,
            MixPlacement::FgpOnly,
            Policy::Baseline,
            FairnessPolicy::RoundRobin,
        )
        .unwrap()
    };
    let shared = run(false);
    let flushed = run(true);
    let x_shared = shared.xlate.unwrap();
    let x_flushed = flushed.xlate.unwrap();

    assert_eq!(
        shared.accesses.ndp_total(),
        flushed.accesses.ndp_total(),
        "flushing changes timing, never the access stream"
    );
    assert_eq!(
        x_shared.l1_hits + x_shared.l1_misses,
        x_flushed.l1_hits + x_flushed.l1_misses
    );
    assert!(
        x_flushed.l1_hits < x_shared.l1_hits,
        "flushing on every switch must cost hits: {} vs {}",
        x_flushed.l1_hits,
        x_shared.l1_hits
    );
    assert!(
        x_flushed.walks > x_shared.walks,
        "the lost translations must be re-walked"
    );
}
